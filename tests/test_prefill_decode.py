"""Serving-path correctness: prefill + single decode step must equal the
full-sequence forward (per arch family, incl. windowed ring-buffer caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

ARCHS = ["qwen3-8b", "gemma3-12b", "xlstm-125m", "zamba2-7b", "whisper-base",
         "pixtral-12b", "gemma-7b", "qwen3-14b", "llama4-maverick-400b-a17b"]


def _pad_kv(c, total, prefill_len):
    """Grow full-length KV caches to `total`; ring (windowed) caches keep
    their length == window (their modulus) and are never padded."""
    if isinstance(c, dict):
        if set(c.keys()) >= {"k", "v"} and c["k"].ndim == 5:
            out = {}
            for kk in ("k", "v"):
                x = c[kk]
                if x.shape[2] == prefill_len and x.shape[2] < total:
                    padw = [(0, 0)] * x.ndim
                    padw[2] = (0, total - x.shape[2])
                    out[kk] = jnp.pad(x, padw)
                else:
                    out[kk] = x
            return out
        return {k: _pad_kv(v, total, prefill_len) for k, v in c.items()}
    if isinstance(c, list):
        return [_pad_kv(v, total, prefill_len) for v in c]
    return c


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_plus_decode_equals_full(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:     # avoid legitimate token-dropping differences
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    B, S = 2, 16
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(key, (B, cfg.num_patches,
                                                   cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_frames,
                                                  cfg.d_model))
    logits_full, _ = M.forward_train(params, built, batch)

    batch_p = dict(batch)
    batch_p["tokens"] = toks[:, :S - 1]
    _, caches = M.forward_prefill(params, built, batch_p)
    caches = _pad_kv(caches, S + cfg.num_patches, S - 1 + cfg.num_patches)
    pos = jnp.asarray(S - 1 + cfg.num_patches, jnp.int32)
    logits_d, _ = M.forward_decode(params, built, toks[:, S - 1:], caches, pos)

    a = np.asarray(logits_full[:, -1])
    b = np.asarray(logits_d[:, 0])
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_windowed_ring_buffer_decode():
    """Sliding-window cache: decode far past the window stays exact."""
    cfg = get_config("gemma3-12b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=8)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    logits_full, _ = M.forward_train(params, built, {"tokens": toks})

    # prefill 12, decode 12 more one at a time
    _, caches = M.forward_prefill(params, built, {"tokens": toks[:, :12]})
    caches = _pad_kv(caches, S, 12)
    for t in range(12, S):
        logits_d, caches = M.forward_decode(params, built, toks[:, t:t + 1],
                                            caches, jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(logits_d[:, 0]),
                               rtol=2e-3, atol=2e-3)
