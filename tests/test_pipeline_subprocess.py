"""The pod-axis split pipeline needs >1 device, so it runs in a subprocess
with its own XLA_FLAGS (the main pytest process must stay single-device)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import model as M
from repro.serving.pipeline import make_split_pipeline, wire_stats

cfg = get_config("qwen3-8b").reduced().with_butterfly(layer=1, d_r=32)
built = M.build(cfg)
params, _ = M.init_model(jax.random.key(0), built)
mesh = jax.make_mesh((2, 1), ("pod", "data"))
Mmb, mb, S = 3, 2, 16
toks = jax.random.randint(jax.random.key(1), (Mmb*mb, S), 0, cfg.vocab_size)
pipe = jax.jit(make_split_pipeline(built, mesh, Mmb, S, mb))
logits = pipe(params, toks)
ref, _ = M.forward_train(params, built, {"tokens": toks})
err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
assert err < 5e-3, err
hlo = jax.jit(pipe).lower(params, toks).compile().as_text()
assert any("collective-permute" in l and "s8[" in l for l in hlo.splitlines()), \
    "wire must cross the pod boundary as int8"
stats = wire_stats(cfg, mb, S)
assert stats["compression"] > 10
print("PIPELINE_OK", err, stats["compression"])
"""


def test_split_pipeline_two_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "PIPELINE_OK" in res.stdout
