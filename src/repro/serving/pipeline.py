"""The paper's deployment, TPU-native: a 2-stage microbatched pipeline over
the ``pod`` mesh axis with the butterfly unit at the stage boundary.

Pod 0 ("edge") computes layers [0, j) + the reduction unit + int8 wire
quantization; a single ``lax.ppermute`` per tick carries ONLY the quantized
codes + f32 scales across the pod boundary (this is the paper's compressed
uplink, visible in the HLO as a collective-permute of an int8 tensor);
pod 1 ("cloud") dequantizes, restores, runs layers [j, N) and the LM head,
and the last-token logits ride the same ppermute back ("the inference
outcome is sent back to the mobile device").

Within a pod, stages are model-parallel (DESIGN.md section 11): when the
mesh carries a ``model`` axis, attention heads / d_ff columns / MoE experts
shard over it Megatron-style and each layer's partial outputs psum over
``model`` — so the "significant computational load on the cloud server"
spreads across the pod's devices while the *only* tensor crossing the pod
axis is still the compressed ``(mb, S, d_r)`` wire.  MoE configs run
expert-parallel inside the 2-pod split (each model rank owns E/mp experts,
``models/moe.py`` manual path).  With no ``model`` axis (or size 1) the
stage params replicate exactly as before.

Scope: scoring/prefill pipeline (the paper's single-forward inference),
dense/ssm/hybrid/MoE archs; decode pipelining is listed as an extension in
DESIGN.md.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.quantization import dequantize, quantize
from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.common import embed, rms_norm, unembed
from repro.models.parallel import LOCAL, manual_context


def wire_stats(cfg, microbatch: int, seq: int) -> dict:
    """Bytes crossing the pod boundary per microbatch tick."""
    d_r = cfg.butterfly.d_r
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    wire = microbatch * seq * d_r * cfg.butterfly.wire_bits // 8 + \
        microbatch * seq * 4
    raw = microbatch * seq * cfg.d_model * act_bytes
    return {"wire_bytes": wire, "raw_boundary_bytes": raw,
            "compression": raw / wire}


def pipeline_param_specs(built: M.BuiltModel, mp: int):
    """PartitionSpec pytree (a prefix of the params tree) for the pipeline's
    shard_map: stage layers shard over the ``model`` axis per the tensor-
    parallel rules, everything else (embeddings, norms, butterfly, LM head)
    replicates.  ``mp == 1`` returns a bare ``P()`` — the fully replicated
    prefix, bit-identical to the pre-model-parallel pipeline."""
    if mp <= 1:
        return P()
    return M.tp_param_specs(built)


def make_split_pipeline(built: M.BuiltModel, mesh, num_microbatches: int,
                        seq_len: int, microbatch: int,
                        wire_mode: str = "int8"):
    """Returns jit-able ``pipeline_fn(params, tokens) -> last-token logits``.

    tokens: (num_microbatches * microbatch, seq_len) int32, sharded over the
    'data' axis on the batch dim; requires a 'pod' axis of size 2.  An
    optional 'model' axis makes each stage tensor-parallel within its pod
    (heads/d_ff/experts must divide the axis — see
    ``transformer.check_tp_divisibility``).

    wire_mode — what crosses the pod boundary (the perf-iteration knob):
      "raw"     vanilla collaborative intelligence: the full (mb, S, d_model)
                activation in model dtype (prior work [6]-[12])
      "reduced" butterfly reduction only, no quantization: (mb, S, d_r) dtype
      "int8"    the paper: reduction + int8 wire (codes + f32 scales)
    """
    cfg = built.cfg
    assert built.has_butterfly and len(built.stages) == 2, \
        "pipeline needs a butterfly split (cfg.with_butterfly(...))"
    assert not cfg.is_encdec, "enc-dec archs are out of pipeline scope"
    n_pods = mesh.shape["pod"]
    assert n_pods == 2, "2-stage pipeline: edge pod + cloud pod"
    axes = mesh.axis_names
    mp = int(mesh.shape["model"]) if "model" in axes else 1
    tfm.check_tp_divisibility(tfm.build_layer_defs(cfg, built.long_mode),
                              cfg, mp)
    pctx = manual_context(mesh) if mp > 1 else LOCAL
    d_r = cfg.butterfly.d_r
    V = cfg.vocab_size
    d = cfg.d_model
    Mmb = num_microbatches
    dt = jnp.dtype(cfg.dtype)

    assert wire_mode in ("raw", "reduced", "int8"), wire_mode

    def stage_edge(params, toks):
        scale = cfg.arch_type == "dense" and cfg.act == "gelu"
        x = embed(params["embed"], toks, scale=scale)
        x, _, _ = tfm.apply_stage(
            list(built.stages[0]), params["stages"][0], x, cfg=cfg,
            pctx=pctx, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        if wire_mode == "raw":
            return x, jnp.zeros((x.shape[0], seq_len, 1), jnp.float32)
        r = x @ params["butterfly"]["w_reduce"]
        if wire_mode == "reduced":
            return r, jnp.zeros((r.shape[0], seq_len, 1), jnp.float32)
        codes, scales = quantize(r, cfg.butterfly.wire_bits)
        return codes, scales

    def stage_cloud(params, codes, scales):
        if wire_mode == "raw":
            x = codes
            x, _, _ = tfm.apply_stage(
                list(built.stages[1]), params["stages"][1], x, cfg=cfg,
                pctx=pctx, mode="train", stage_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x)[:, 0]
        r = codes if wire_mode == "reduced" else dequantize(codes, scales, dt)
        x = r @ params["butterfly"]["w_restore"]
        x, _, _ = tfm.apply_stage(
            list(built.stages[1]), params["stages"][1], x, cfg=cfg,
            pctx=pctx, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(table, x)[:, 0]                      # (mb, V)

    def shard_body(params, tokens):
        pod = jax.lax.axis_index("pod")
        mb_toks = tokens.reshape(Mmb, -1, seq_len)
        mb = mb_toks.shape[1]

        if wire_mode == "raw":
            wire_shape, wire_dtype = (mb, seq_len, d), dt
        elif wire_mode == "reduced":
            wire_shape, wire_dtype = (mb, seq_len, d_r), dt
        else:
            wire_shape, wire_dtype = (mb, seq_len, d_r), jnp.int8
        zero_wire = (jnp.zeros(wire_shape, wire_dtype),
                     jnp.zeros((mb, seq_len, 1), jnp.float32))
        zero_logits = jnp.zeros((mb, V), jnp.float32)

        def tick(t, carry):
            recv_codes, recv_scales, out, back = carry

            # each branch runs only on its pod's ranks; the model-axis psums
            # inside the stages reduce within the pod (disjoint replica
            # groups per pod), so neither branch communicates across pods
            def edge(_):
                i = jnp.clip(t, 0, Mmb - 1)
                toks = jax.lax.dynamic_index_in_dim(mb_toks, i, 0, False)
                codes, scales = stage_edge(params, toks)
                return codes, scales, zero_logits

            def cloud(_):
                logits = stage_cloud(params, recv_codes, recv_scales)
                return zero_wire[0], zero_wire[1], logits

            codes, scales, logits = jax.lax.cond(pod == 0, edge, cloud, None)
            # the wire: int8 codes + scales cross 0 -> 1; logits cross 1 -> 0
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])
            logits_back = jax.lax.ppermute(logits, "pod", [(0, 1), (1, 0)])
            out = jnp.where(t >= 1, out.at[jnp.maximum(t - 1, 0)].set(logits),
                            out)
            back = jnp.where(t >= 1, back.at[jnp.maximum(t - 1, 0)].set(logits_back),
                             back)
            return codes, scales, out, back

        out0 = jnp.zeros((Mmb, mb, V), jnp.float32)
        carry = (*zero_wire, out0, out0)
        *_, out, back = jax.lax.fori_loop(0, Mmb + 1, tick, carry)
        # pod 1 filled `out` locally; pod 0 received `back`. Select the live
        # copy so the caller-visible result is pod-invariant.
        result = jnp.where(pod == 0, back, out)
        return result[None]                                  # add pod dim

    data_ax = "data" if "data" in axes else None
    fn = compat.shard_map(
        shard_body, mesh=mesh,
        in_specs=(pipeline_param_specs(built, mp), P(data_ax, None)),
        out_specs=P("pod", None, data_ax, None),
        check_vma=False,
    )

    def pipeline_fn(params, tokens):
        res = fn(params, tokens)
        return res[0].reshape(-1, V)                         # pod 0's copy

    return pipeline_fn
