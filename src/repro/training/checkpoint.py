"""Numpy .npz checkpoints with pytree flattening (no orbax dependency).

Keys encode the tree path; restore rebuilds against a template tree so list/
dict structure (including the stacked segment params) round-trips exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata: dict | None = None) -> str:
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten_with_paths(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten_with_paths(opt_state).items()})
    np.savez(path, **payload)
    meta = dict(metadata or {}, step=step)
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)
    return path


def restore_checkpoint(path: str, params_template, opt_template=None):
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)

    def rebuild(template, prefix):
        flat, tdef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = prefix + "/".join(str(x.key) if hasattr(x, "key") else str(x.idx)
                                    for x in p)
            arr = jnp.asarray(data[key], dtype=leaf.dtype)
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(tdef, leaves)

    params = rebuild(params_template, "params/")
    opt = rebuild(opt_template, "opt/") if opt_template is not None else None
    meta = {}
    if os.path.exists(path + ".meta.json"):
        with open(path + ".meta.json") as f:
            meta = json.load(f)
    return params, opt, meta
