"""Sharded MoE correctness vs the local path, on a small host-device mesh
(subprocess: needs its own XLA_FLAGS before jax init).  Covers both the
train path (FSDP weight all-gather) and the decode broadcast path."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_MOE_DECODE_BROADCAST"] = "1"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.models.parallel import LOCAL, make_context

cfg = get_config("qwen3-moe-235b-a22b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=4, top_k=2, capacity_factor=100.0, d_ff_expert=128))
params, specs = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
pctx = make_context(mesh)

# expert weights: experts over model; ff over data where divisible
ff_ax = "data" if cfg.moe.d_ff_expert % 16 == 0 else None
# NB: reduced d_ff_expert=128 % 16 == 0 -> ff sharded over data(2)? 128%16==0
# but our mesh data axis is 2 -> P uses divisibility by axis size at runtime.

def put(x, spec):
    return jax.device_put(x, NamedSharding(mesh, spec))

params_sh = {
    "router": put(params["router"], P(None, None)),
    "wg": put(params["wg"], P("model", None, "data")),
    "wu": put(params["wu"], P("model", None, "data")),
    "wd": put(params["wd"], P("model", "data", None)),
}

# --- train path: (B,S) = (4, 8), batch over data ---
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model)) * 0.5
x_sh = put(x, P("data", None, None))
out_local, aux_l = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
fn = jax.jit(lambda p, xx: moe_lib.apply_moe(p, xx, cfg=cfg, pctx=pctx, act="silu"))
out_sh, aux_s = fn(params_sh, x_sh)
err = float(jnp.max(jnp.abs(out_local - out_sh)))
assert err < 1e-4, ("train path", err)

# --- decode path: (B,S) = (8, 1) ---
xd = jax.random.normal(jax.random.key(2), (8, 1, cfg.d_model)) * 0.5
xd_sh = put(xd, P("data", None, None))
outd_local, _ = moe_lib.apply_moe(params, xd, cfg=cfg, pctx=LOCAL, act="silu")
assert moe_lib.DECODE_BROADCAST
outd_sh, _ = jax.jit(lambda p, xx: moe_lib.apply_moe(p, xx, cfg=cfg, pctx=pctx,
                                                     act="silu"))(params_sh, xd_sh)
errd = float(jnp.max(jnp.abs(outd_local - outd_sh)))
assert errd < 1e-4, ("decode path", errd)
print("MOE_SHARDED_OK", err, errd)
"""


def test_moe_sharded_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MOE_SHARDED_OK" in res.stdout


CODE_POD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_MOE_EXPERTS_OVER_POD"] = "1"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.models.parallel import LOCAL, make_context

cfg = get_config("qwen3-moe-235b-a22b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=4, top_k=2, capacity_factor=100.0, d_ff_expert=128))
params, specs = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pctx = make_context(mesh)

def put(x, spec):
    return jax.device_put(x, NamedSharding(mesh, spec))

params_sh = {
    "router": put(params["router"], P(None, None)),
    "wg": put(params["wg"], P(("pod", "model"), None, "data")),
    "wu": put(params["wu"], P(("pod", "model"), None, "data")),
    "wd": put(params["wd"], P(("pod", "model"), "data", None)),
}
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model)) * 0.5
x_sh = put(x, P(("pod", "data"), None, None))
out_local, _ = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
out_sh, _ = jax.jit(lambda p, xx: moe_lib.apply_moe(p, xx, cfg=cfg, pctx=pctx,
                                                    act="silu"))(params_sh, x_sh)
err = float(jnp.max(jnp.abs(out_local - out_sh)))
assert err < 1e-4, err
print("MOE_POD_OK", err)
"""


def test_moe_experts_over_pod_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", CODE_POD], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MOE_POD_OK" in res.stdout
