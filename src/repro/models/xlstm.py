"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with exponential gating).

Simplifications vs. the reference CUDA implementation (documented in
DESIGN.md): the mLSTM max-stabilizer ``m_t`` is replaced by the bounded
log-sigmoid forget-gate cumulative form (all decays <= 1, so the chunkwise
exponentials cannot overflow) and the denominator uses the paper's
``max(|q . n|, 1)`` floor.  sLSTM keeps the full i/f/z/o exponential-gating
recurrence with the stabilizer, block-diagonal (per-head) recurrent weights,
run under ``lax.scan``.

Decode state:
  mLSTM: {"C": (B,H,P,P) f32, "n": (B,H,P) f32, "conv": (B,W-1,d_inner)}
  sLSTM: {"c","n","h","m": (B,H,P) f32}
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, dense_spec, rms_norm
from repro.models.parallel import ParallelContext


def _mlstm_dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    Pd = d_inner // H
    return x, d_inner, H, Pd


def _slstm_dims(cfg: ModelConfig):
    H = cfg.num_heads
    Pd = cfg.d_model // H
    return H, Pd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype):
    x, d_inner, H, Pd = _mlstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    params = {
        "up_z": dense_init(ks[0], d, d_inner, dtype),
        "up_x": dense_init(ks[1], d, d_inner, dtype),
        "conv_w": (jax.random.normal(ks[2], (x.conv_width, d_inner), jnp.float32)
                   / math.sqrt(x.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": dense_init(ks[3], d_inner, d_inner, dtype),
        "wk": dense_init(ks[4], d_inner, d_inner, dtype),
        "wv": dense_init(ks[5], d_inner, d_inner, dtype),
        "w_if": dense_init(ks[6], d_inner, 2 * H, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "down": dense_init(ks[7], d_inner, d, dtype, scale=1.0 / d_inner),
    }
    specs = {
        "up_z": dense_spec((d, d_inner), 1), "up_x": dense_spec((d, d_inner), 1),
        "conv_w": P(None, None), "conv_b": P(None),
        "wq": P(None, None), "wk": P(None, None), "wv": P(None, None),
        "w_if": P(None, None), "b_if": P(None),
        "norm_w": P(None), "down": dense_spec((d_inner, d), 0),
    }
    return params, specs


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    x, d_inner, H, Pd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, Pd, Pd), jnp.float32),
        "n": jnp.zeros((batch, H, Pd), jnp.float32),
        "conv": jnp.zeros((batch, x.conv_width - 1, d_inner), dtype),
    }


def _mlstm_gates(params, xi):
    """xi: (B,S,d_inner) -> log_i, log_f (B,S,H) in f32, bounded."""
    g = xi.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    H = g.shape[-1] // 2
    log_i = -jax.nn.softplus(-g[..., :H])       # log sigmoid(i~): <= 0
    log_f = -jax.nn.softplus(-g[..., H:])       # log sigmoid(f~): <= 0
    return log_i, log_f


def _conv_silu(xi, conv_w, conv_b, width):
    out = xi * conv_w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xi, ((0, 0), (i, 0), (0, 0)))[:, :xi.shape[1]]
        out = out + shifted * conv_w[-1 - i]
    return jax.nn.silu(out + conv_b)


def mlstm_fullseq(params, x, *, cfg: ModelConfig, return_state: bool = False):
    xcfg, d_inner, H, Pd = _mlstm_dims(cfg)
    Bsz, S, _ = x.shape
    L = min(xcfg.chunk_size, S)
    assert S % L == 0
    C = S // L

    z = jax.nn.silu(x @ params["up_z"])
    xi = x @ params["up_x"]
    xi = _conv_silu(xi, params["conv_w"], params["conv_b"], xcfg.conv_width)
    q = (xi @ params["wq"]).reshape(Bsz, S, H, Pd) / math.sqrt(Pd)
    k = (xi @ params["wk"]).reshape(Bsz, S, H, Pd)
    v = (xi @ params["wv"]).reshape(Bsz, S, H, Pd)
    log_i, log_f = _mlstm_gates(params, xi)

    qc = q.reshape(Bsz, C, L, H, Pd).astype(jnp.float32)
    kc = k.reshape(Bsz, C, L, H, Pd).astype(jnp.float32)
    vc = v.reshape(Bsz, C, L, H, Pd).astype(jnp.float32)
    lic = log_i.reshape(Bsz, C, L, H)
    cumf = jnp.cumsum(log_f.reshape(Bsz, C, L, H), axis=2)        # <= 0

    # intra-chunk: D[i,j] = exp(cumf_i - cumf_j + log_i_j), i >= j
    seg = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + lic[:, :, None, :, :]
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    # mask before exp (see ssm.py): avoids 0 * inf = NaN in the backward
    D = jnp.exp(jnp.where(mask, seg, -1e9))                       # (B,C,L,L,H)
    scores = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)
    num_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * D, vc)
    den_intra = jnp.einsum("bcijh->bcih", scores * D)

    # chunk state contributions
    last = cumf[:, :, -1:, :]
    w = jnp.exp(last - cumf + lic)                                # (B,C,L,H)
    C_chunk = jnp.einsum("bclh,bclhp,bclhq->bchpq", w, vc, kc)    # v k^T
    n_chunk = jnp.einsum("bclh,bclhp->bchp", w, kc)
    chunk_decay = jnp.exp(last[:, :, 0, :])

    def step(carry, inputs):
        Cs, ns = carry
        C_c, n_c, dec, q_c, cumf_c = inputs
        yq = jnp.einsum("blhp,bhqp->blhq", q_c, Cs) * jnp.exp(cumf_c)[..., None]
        dq = jnp.einsum("blhp,bhp->blh", q_c, ns) * jnp.exp(cumf_c)
        Cs = Cs * dec[:, :, None, None] + C_c
        ns = ns * dec[:, :, None] + n_c
        return (Cs, ns), (yq, dq)

    init = (jnp.zeros((Bsz, H, Pd, Pd), jnp.float32),
            jnp.zeros((Bsz, H, Pd), jnp.float32))
    xs_scan = (C_chunk.transpose(1, 0, 2, 3, 4), n_chunk.transpose(1, 0, 2, 3),
               chunk_decay.transpose(1, 0, 2), qc.transpose(1, 0, 2, 3, 4),
               cumf.transpose(1, 0, 2, 3))
    (C_fin, n_fin), (num_inter, den_inter) = jax.lax.scan(step, init, xs_scan)
    num = num_intra + num_inter.transpose(1, 0, 2, 3, 4)
    den = den_intra + den_inter.transpose(1, 0, 2, 3)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.rms_eps) * z
    out = y @ params["down"]
    if return_state:
        state = {"C": C_fin, "n": n_fin,
                 "conv": (x @ params["up_x"])[:, -(xcfg.conv_width - 1):, :]}
        return out, state
    return out, None


def mlstm_decode(params, x, state, *, cfg: ModelConfig):
    xcfg, d_inner, H, Pd = _mlstm_dims(cfg)
    Bsz = x.shape[0]
    z = jax.nn.silu(x @ params["up_z"])[:, 0]                     # (B,di)
    xi_new = (x @ params["up_x"])                                  # (B,1,di)
    window = jnp.concatenate([state["conv"], xi_new], axis=1)
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xi = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    q = (xi @ params["wq"]).reshape(Bsz, H, Pd).astype(jnp.float32) / math.sqrt(Pd)
    k = (xi @ params["wk"]).reshape(Bsz, H, Pd).astype(jnp.float32)
    v = (xi @ params["wv"]).reshape(Bsz, H, Pd).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(params, xi[:, None, :])
    i_t = jnp.exp(log_i[:, 0])                                    # (B,H)
    f_t = jnp.exp(log_f[:, 0])

    C = state["C"] * f_t[:, :, None, None] + \
        i_t[:, :, None, None] * jnp.einsum("bhp,bhq->bhpq", v, k)
    n = state["n"] * f_t[:, :, None] + i_t[:, :, None] * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)), 1.0)
    y = (num / den[..., None]).reshape(Bsz, d_inner).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.rms_eps) * z
    out = (y @ params["down"])[:, None, :]
    return out, {"C": C, "n": n, "conv": window[:, 1:, :].astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype):
    H, Pd = _slstm_dims(cfg)
    d = cfg.d_model
    d_ff = max(int(d * 8 / 3) // 64 * 64, 64)
    ks = jax.random.split(key, 4)
    params = {
        "w_in": dense_init(ks[0], d, 4 * d, jnp.float32),          # i,f,z,o pre-acts
        "r": (jax.random.normal(ks[1], (4, H, Pd, Pd), jnp.float32)
              / math.sqrt(Pd)),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]),
        "norm_w": jnp.zeros((d,), dtype),
        "w_ff1": dense_init(ks[2], d, d_ff, dtype),
        "w_ff2": dense_init(ks[3], d_ff, d, dtype, scale=1.0 / d_ff),
    }
    specs = {
        "w_in": P(None, None), "r": P(None, None, None, None), "b": P(None),
        "norm_w": P(None),
        "w_ff1": dense_spec((d, d_ff), 1), "w_ff2": dense_spec((d_ff, d), 0),
    }
    return params, specs


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, Pd = _slstm_dims(cfg)
    zeros = jnp.zeros((batch, H, Pd), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros, "m": zeros - 10.0}


def _slstm_step(params, carry, pre, H, Pd):
    """One sLSTM time-step. pre: (B, 4d) input pre-activations (f32)."""
    c, n, h, m = carry
    B = pre.shape[0]
    pre = pre.reshape(B, 4, H, Pd)
    rec = jnp.einsum("ghpq,bhq->gbhp", params["r"], h).transpose(1, 0, 2, 3)
    g = pre + rec                                                  # (B,4,H,P)
    gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    log_f = -jax.nn.softplus(-gf)                                  # log sigmoid
    m_new = jnp.maximum(log_f + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new), h


def slstm_fullseq(params, x, *, cfg: ModelConfig, return_state: bool = False):
    H, Pd = _slstm_dims(cfg)
    Bsz, S, d = x.shape
    pre = (x.astype(jnp.float32) @ params["w_in"] + params["b"])   # (B,S,4d)
    init = (jnp.zeros((Bsz, H, Pd), jnp.float32),) * 3 + \
           (jnp.full((Bsz, H, Pd), -10.0, jnp.float32),)

    def step(carry, p):
        return _slstm_step(params, carry, p, H, Pd)

    carry, hs = jax.lax.scan(step, init, pre.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(Bsz, S, d).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.rms_eps)
    y = y + jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"]
    if return_state:
        c, n, h, m = carry
        return y, {"c": c, "n": n, "h": h, "m": m}
    return y, None


def slstm_decode(params, x, state, *, cfg: ModelConfig):
    H, Pd = _slstm_dims(cfg)
    Bsz, _, d = x.shape
    pre = (x[:, 0].astype(jnp.float32) @ params["w_in"] + params["b"])
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, h = _slstm_step(params, carry, pre, H, Pd)
    y = h.reshape(Bsz, 1, d).astype(x.dtype)
    y = rms_norm(y, params["norm_w"], cfg.rms_eps)
    y = y + jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"]
    c, n, hh, m = carry
    return y, {"c": c, "n": n, "h": hh, "m": m}
