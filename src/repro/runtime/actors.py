"""Edge-device fleet and cloud continuous-batching server.

EdgeDevice is a serial processor (one prefill at a time, like a phone's NPU):
requests queue at the device, run the edge half (layers [0, split) + the
butterfly reduce/quantize), then contend for the shared uplink.

CloudServer is a serial accelerator running a continuous-batching loop over
the hosted partitioned models (one ServingEngine per split): it alternates
admitting one pending prefill (restore + layers [split, N) + LM head) and
running one batched decode step over all active slots — exactly the
ServingEngine's "prefill one at a time, decode batched" discipline, but on
the virtual clock, with service times derated by ``1/(1 - load)`` (the
paper's K_cloud congestion knob).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.runtime.clock import EventLoop
from repro.runtime.split_exec import CostModel, SplitModelBank
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.wire import Uplink


@dataclass
class SimRequest:
    trace: RequestTrace
    tokens: Optional[np.ndarray] = None       # prompt (numerics mode)
    max_new_tokens: int = 1
    payload: Optional[tuple] = None           # (codes, scales, stage0_cache)
    engine_req: object = None                 # serving.engine.Request
    slot: int = -1                            # cloud slot (virtual accounting)

    @property
    def uid(self) -> int:
        return self.trace.uid


class EdgeDevice:
    """Serial edge processor feeding a shared uplink."""

    def __init__(self, dev_id: int, *, loop: EventLoop, cost: CostModel,
                 uplink: Uplink, server: "CloudServer",
                 bank: Optional[SplitModelBank], mode: str, wire_mode: str,
                 d_r: int, telemetry: Telemetry, numerics_split: int = 1):
        self.dev_id = dev_id
        self.numerics_split = numerics_split
        self.loop = loop
        self.cost = cost
        self.uplink = uplink
        self.server = server
        self.bank = bank
        self.mode = mode
        self.wire_mode = wire_mode
        self.d_r = d_r
        self.telemetry = telemetry
        self.free_at = 0.0
        self._local_engine = None

    def on_arrival(self, req: SimRequest) -> None:
        t = req.trace
        t.t_arrival = self.loop.now
        start = max(self.loop.now, self.free_at)
        S = t.prompt_len
        if self.mode == "split":
            dur = self.cost.edge_prefill_s(t.split, S, self.d_r)
        elif self.mode == "edge":
            dur = self.cost.full_prefill_s(S, where="edge")
            dur += sum(self.cost.decode_step_s(1, where="edge")
                       for _ in range(max(req.max_new_tokens - 1, 0)))
        else:                                   # cloud-only: capture + ship
            dur = 0.0
        t.t_edge_start = start
        t.t_edge_done = start + dur
        self.free_at = t.t_edge_done
        self.loop.schedule_at(t.t_edge_done, lambda: self._edge_done(req))

    def _edge_done(self, req: SimRequest) -> None:
        t = req.trace
        t.mobile_energy_mj += self.cost.edge_energy_mj(t.edge_compute_s)
        if self.mode == "split" and self.bank is not None:
            runner = self.bank.runner(t.split)
            payload, scales, cache0 = runner.edge_half(runner.params,
                                                       req.tokens[None])
            req.payload = (payload, scales, cache0)
        if self.mode == "edge":
            self._finish_local(req)
            return
        nbytes = self.cost.payload_bytes(self.mode, self.wire_mode,
                                         t.prompt_len, self.d_r, t.split,
                                         req.max_new_tokens)
        t.wire_bytes = nbytes
        start, done = self.uplink.transfer(nbytes, self.loop.now)
        t.t_uplink_start, t.t_uplink_done = start, done
        t.mobile_energy_mj += self.uplink.transfer_energy_mj(nbytes)
        self.loop.schedule_at(done, lambda: self.server.on_payload(req))

    def _finish_local(self, req: SimRequest) -> None:
        """Mobile-only baseline: everything already ran on the device."""
        t = req.trace
        t.t_uplink_start = t.t_uplink_done = t.t_cloud_start = t.t_edge_done
        t.t_first_token = t.t_done = t.t_edge_done
        if self.bank is not None:
            # mobile-only runs the same hosted model (split is a no-op for
            # numerics when both halves share a device); one engine per
            # device, reused across its serial requests
            if self._local_engine is None:
                runner = self.bank.runner(self.numerics_split)
                self._local_engine = runner.make_engine(
                    max_batch=1, max_len=self.server.max_len)
            eng = self._local_engine
            req.engine_req = eng.submit(req.tokens,
                                        max_new_tokens=req.max_new_tokens)
            eng.run()
            t.new_tokens = len(req.engine_req.generated)
        else:
            t.new_tokens = req.max_new_tokens
        self.telemetry.record(t)
        self.server.sim_request_done(req)


class CloudServer:
    """Serial accelerator + slot pool running continuous batching."""

    def __init__(self, *, loop: EventLoop, cost: CostModel,
                 bank: Optional[SplitModelBank], mode: str, d_r: int,
                 telemetry: Telemetry, max_concurrent: int = 8,
                 background_load: Optional[Callable[[float], float]] = None,
                 engine_seed: int = 0, max_len: int = 256,
                 on_done: Optional[Callable[[SimRequest], None]] = None,
                 numerics_split: int = 1):
        self.numerics_split = numerics_split
        self.loop = loop
        self.cost = cost
        self.bank = bank
        self.mode = mode
        self.d_r = d_r
        self.telemetry = telemetry
        self.max_concurrent = max_concurrent
        self.background_load = background_load or (lambda t: 0.0)
        self.max_len = max_len
        self.engine_seed = engine_seed
        self.on_done = on_done
        self.pending: deque[SimRequest] = deque()
        self.slots: List[Optional[SimRequest]] = [None] * max_concurrent
        self.slot_history: List[tuple] = []       # (uid, slot) admissions
        self._engines: Dict[int, object] = {}     # split -> ServingEngine
        self._virtual_left: Dict[int, int] = {}   # uid -> decode steps left
        self._busy = False
        self.peak_active = 0

    # -- load signal --------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(1 for r in self.slots if r is not None)

    def current_load(self, now: float) -> float:
        """Combined congestion the mobile observes when it pings the server:
        external tenants (background) plus this fleet's own occupancy."""
        bg = min(max(self.background_load(now), 0.0), 0.99)
        occ = self.num_active / self.max_concurrent
        return min(1.0 - (1.0 - bg) * (1.0 - occ), 0.99)

    # -- request flow -------------------------------------------------------
    def on_payload(self, req: SimRequest) -> None:
        self.pending.append(req)
        self._kick()

    def _kick(self) -> None:
        if not self._busy:
            self._busy = True
            self.loop.schedule(0.0, self._service)

    def _engine(self, split: int):
        if self.bank is None:
            return None
        if self.mode != "split":
            split = self.numerics_split   # cloud-only runs one hosted model
        if split not in self._engines:
            self._engines[split] = self.bank.runner(split).make_engine(
                max_batch=self.max_concurrent, max_len=self.max_len,
                seed=self.engine_seed)
        return self._engines[split]

    def _free_slot(self) -> int:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return -1

    def _service(self) -> None:
        now = self.loop.now
        slot = self._free_slot()
        if self.pending and slot >= 0:
            req = self.pending.popleft()
            self._admit(req, slot, now)
            return
        if self.num_active > 0:
            self._decode_step(now)
            return
        self._busy = False

    def _admit(self, req: SimRequest, slot: int, now: float) -> None:
        t = req.trace
        t.t_cloud_start = now
        load = min(max(self.background_load(now), 0.0), 0.99)
        S = t.prompt_len
        if self.mode == "split":
            dur = self.cost.cloud_prefill_s(t.split, S, self.d_r, load)
        else:
            dur = self.cost.full_prefill_s(S, where="cloud", load=load)
        req.slot = slot
        self.slots[slot] = req
        self.slot_history.append((t.uid, slot))
        self.peak_active = max(self.peak_active, self.num_active)
        self.loop.schedule(dur, lambda: self._prefill_done(req))

    def _prefill_done(self, req: SimRequest) -> None:
        t = req.trace
        t.t_first_token = self.loop.now
        eng = self._engine(t.split)
        if eng is not None:
            if self.mode == "split":
                runner = self.bank.runner(t.split)
                payload, scales, cache0 = req.payload
                logits, cache1 = runner.cloud_half(runner.params, payload,
                                                   scales)
                req.engine_req = eng.submit_prefilled(
                    t.prompt_len, [cache0, cache1], logits[0],
                    max_new_tokens=req.max_new_tokens)
            else:
                req.engine_req = eng.submit(
                    req.tokens, max_new_tokens=req.max_new_tokens)
            req.payload = None
            if req.engine_req.done:
                self._complete(req)
        else:
            self._virtual_left[t.uid] = req.max_new_tokens - 1
            if self._virtual_left[t.uid] <= 0:
                self._complete(req)
        self.loop.schedule(0.0, self._service)

    def _decode_step(self, now: float) -> None:
        batch = self.num_active
        load = min(max(self.background_load(now), 0.0), 0.99)
        dur = self.cost.decode_step_s(batch, where="cloud", load=load)
        self.loop.schedule(dur, self._decode_done)

    def _decode_done(self) -> None:
        if self.bank is not None:
            stepped = set()
            for req in list(self.slots):
                if req is None:
                    continue
                eng = self._engine(req.trace.split)
                if id(eng) not in stepped:
                    eng.step()
                    stepped.add(id(eng))
            for req in list(self.slots):
                if req is not None and req.engine_req.done:
                    self._complete(req)
        else:
            for req in list(self.slots):
                if req is None:
                    continue
                self._virtual_left[req.uid] -= 1
                if self._virtual_left[req.uid] <= 0:
                    self._complete(req)
        self.loop.schedule(0.0, self._service)

    def _complete(self, req: SimRequest) -> None:
        t = req.trace
        t.t_done = self.loop.now
        if req.engine_req is not None:
            t.new_tokens = len(req.engine_req.generated)
        else:
            t.new_tokens = req.max_new_tokens
        if req.slot >= 0:
            self.slots[req.slot] = None
        self.telemetry.record(t)
        self.sim_request_done(req)

    def sim_request_done(self, req: SimRequest) -> None:
        if self.on_done is not None:
            self.on_done(req)
