"""Decode transports: streamed per-token rows vs stage-0 cache handoff.

Covers the Wire's new downlink (FIFO contention per direction or shared),
the wireless downlink models, transport parity (streamed greedy token
streams must be bitwise-identical to cache handoff and the hosted
single-mesh reference for every wire mode), the flat-uplink regression
(streamed uplink bytes must not grow with prompt length beyond the prefill
codes, while handoff bytes do), and (split, transport) co-selection in the
planner and the closed-loop controller."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costs import TOKEN_BYTES
from repro.core.planner import select_split_online, wire_mode_bytes
from repro.core.profiler import GTX_1080TI, JETSON_TX2
from repro.core.wireless import INTER_POD, NETWORKS
from repro.runtime.simulator import (SimConfig, Simulation, poisson_arrivals)
from repro.runtime.transports import get_transport
from repro.runtime.wire import Wire


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=4,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


# ---------------------------------------------------------------------------
# wire: downlink + duplex contention
# ---------------------------------------------------------------------------


def test_wireless_downlink_models():
    net = NETWORKS["3g"]
    # asymmetric: 3.15 Mbps down vs 1.1 Mbps up
    assert net.downlink_seconds(1e6) == pytest.approx(8.0 / 3.15)
    assert net.downlink_seconds(1e6) < net.uplink_seconds(1e6)
    # downlink radio power uses the MobiSys'12 alpha_d
    assert net.downlink_power_mw() == pytest.approx(
        122.12 * 3.15 + 817.88)
    assert net.downlink_energy_mj(1000) > 0
    # the interconnect is symmetric
    assert INTER_POD.downlink_seconds(1e9) == INTER_POD.uplink_seconds(1e9)


def test_downlink_fifo_contention_and_stats():
    net = NETWORKS["3g"]
    w = Wire(net)                          # duplex="split": independent FIFOs
    dur = net.downlink_seconds(10_000)
    s1, d1 = w.transfer_down(10_000, 0.0)
    s2, d2 = w.transfer_down(10_000, 0.0)  # same instant: must queue
    assert (s1, d1) == (0.0, pytest.approx(dur))
    assert s2 == pytest.approx(d1) and d2 == pytest.approx(2 * dur)
    assert w.down_stats.wait_s == pytest.approx(dur)
    assert w.down_stats.bytes_sent == 20_000
    assert w.down_stats.energy_mj == pytest.approx(
        2 * net.downlink_energy_mj(10_000))
    # split duplex: the uplink frontier is untouched by downlink traffic
    su, du = w.transfer(1000, 0.0)
    assert su == 0.0
    # rtt combines both directions at nominal rates
    assert w.rtt_s(1000, 4) == pytest.approx(
        net.uplink_seconds(1000) + net.downlink_seconds(4))


def test_shared_duplex_serializes_both_directions():
    net = NETWORKS["3g"]
    w = Wire(net, duplex="shared")
    _, d_up = w.transfer(10_000, 0.0)
    s_dn, d_dn = w.transfer_down(4, 0.0)   # must wait for the uplink drain
    assert s_dn == pytest.approx(d_up)
    s_up2, _ = w.transfer(100, 0.0)        # and vice versa
    assert s_up2 == pytest.approx(d_dn)


# ---------------------------------------------------------------------------
# scheduler semantics (timing-only)
# ---------------------------------------------------------------------------


def test_streamed_traces_complete_and_breakdown_sums():
    sim = Simulation(timing_cfg(transport="streamed"))
    tel = sim.run()
    assert len(tel.traces) == 16
    for t in tel.traces:
        assert t.transport == "streamed"
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)
        assert t.downlink_bytes == TOKEN_BYTES * t.new_tokens
        assert t.new_tokens == 4
        assert t.stream_steps == 3            # per token after the first
        assert t.stream_rtt_s > 0
        assert t.t_arrival <= t.t_edge_start <= t.t_edge_done \
            <= t.t_uplink_start <= t.t_uplink_done <= t.t_cloud_start \
            <= t.t_first_token <= t.t_cloud_done <= t.t_done
    # every decode step crossed the wire: prefill + (T-1) rows per request
    assert sim.uplink.stats.n_transfers == 16 * 4
    assert tel.counters["stream_rows"] == 16 * 3


def test_handoff_downlink_ships_ids_once():
    tel = Simulation(timing_cfg(transport="cache_handoff")).run()
    for t in tel.traces:
        assert t.transport == "cache_handoff"
        assert t.downlink_bytes == TOKEN_BYTES * t.new_tokens
        assert t.stream_steps == 0
        # batch return: the mobile's first token arrives with the last, so
        # TTFT is stamped at delivery — same observation point as streamed
        assert t.t_first_token == t.t_done
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)


def test_streamed_uplink_flat_in_prompt_len():
    """The regression the transport exists for: past the prefill codes,
    streamed uplink bytes must not grow with prompt length, while the
    cache handoff's stage-0 KV bytes grow linearly."""
    totals = {}
    for tp in ("cache_handoff", "streamed"):
        for S in (32, 128):
            tel = Simulation(timing_cfg(transport=tp, prompt_len=S,
                                        num_requests=8)).run()
            totals[(tp, S)] = sum(t.wire_bytes for t in tel.traces)
    codes_delta = 8 * (wire_mode_bytes(small_cfg(), 128, 16, "int8") -
                       wire_mode_bytes(small_cfg(), 32, 16, "int8"))
    stream_growth = totals[("streamed", 128)] - totals[("streamed", 32)]
    handoff_growth = totals[("cache_handoff", 128)] - \
        totals[("cache_handoff", 32)]
    assert stream_growth == pytest.approx(codes_delta)      # codes only
    assert handoff_growth > 4 * stream_growth               # + KV cache
    assert totals[("streamed", 128)] < totals[("cache_handoff", 128)]


def test_streamed_deterministic_replay():
    a = Simulation(timing_cfg(transport="streamed")).run()
    b = Simulation(timing_cfg(transport="streamed")).run()
    ka = [(t.uid, t.t_done, t.wire_bytes, t.downlink_bytes) for t in a.traces]
    kb = [(t.uid, t.t_done, t.wire_bytes, t.downlink_bytes) for t in b.traces]
    assert ka == kb


def test_shared_arrival_trace_is_identical_across_transports():
    arr = poisson_arrivals(num_devices=4, num_requests=16, arrival_rate=20.0,
                           prompt_len=32, seed=0)
    t_h = Simulation(timing_cfg(transport="cache_handoff", arrivals=arr)).run()
    t_s = Simulation(timing_cfg(transport="streamed", arrivals=arr)).run()
    assert [(t.uid, t.device, round(t.t_arrival, 12)) for t in t_h.traces] \
        == [(t.uid, t.device, round(t.t_arrival, 12)) for t in t_s.traces]
    # and the default (builder-less) path produces the same trace
    t_d = Simulation(timing_cfg(transport="cache_handoff")).run()
    assert [round(t.t_arrival, 12) for t in t_d.traces] \
        == [round(t.t_arrival, 12) for t in t_h.traces]


# ---------------------------------------------------------------------------
# transport selection (planner + controller)
# ---------------------------------------------------------------------------


def test_planner_scores_transport_pairs():
    cfg = small_cfg()
    cost_kw = dict(candidate_splits=[1, 2, 3], edge=JETSON_TX2,
                   cloud=GTX_1080TI, wire_mode="int8",
                   link_bytes_per_s=NETWORKS["3g"].uplink_mbps * 1e6 / 8,
                   downlink_bytes_per_s=NETWORKS["3g"]._down_mbps * 1e6 / 8,
                   transports=("cache_handoff", "streamed"))
    # long prompt, long generation, heavy per-layer handoff bytes: the KV
    # shipment dominates and streaming wins
    best, rows = select_split_online(
        cfg, 512, 16, new_tokens=32, handoff_bytes_per_layer=2e5, **cost_kw)
    assert len(rows) == 6                    # (split x transport) pairs
    assert best["transport"] == "streamed"
    # single-token requests tie on decode cost: handoff (listed first) wins
    best, _ = select_split_online(
        cfg, 32, 16, new_tokens=1, handoff_bytes_per_layer=0.0, **cost_kw)
    assert best["transport"] == "cache_handoff"
    # short prompt + tiny handoff vs many RTTs on a slow downlink: handoff
    slow = dict(cost_kw, downlink_bytes_per_s=50.0)
    best, _ = select_split_online(
        cfg, 4, 16, new_tokens=32, handoff_bytes_per_layer=16.0, **slow)
    assert best["transport"] == "cache_handoff"


def test_controller_auto_picks_streamed_for_long_prompts():
    sc = timing_cfg(transport="auto", adapt=True, prompt_len=128,
                    max_new_tokens=8, num_requests=8, control_interval_s=0.02)
    sim = Simulation(sc)
    tel = sim.run()
    assert tel.decisions
    assert all(d.transport == "streamed" for d in tel.decisions), \
        "128-token 3g prompts: the KV handoff should always lose"
    # requests arriving after the first decision carry the picked transport
    t0 = tel.decisions[0].t
    picked = {t.transport for t in tel.traces if t.t_arrival > t0}
    assert picked == {"streamed"}


def test_get_transport_registry():
    assert get_transport("streamed").streams_tokens
    assert not get_transport("cache_handoff").streams_tokens
    with pytest.raises(KeyError):
        get_transport("carrier_pigeon")


# ---------------------------------------------------------------------------
# end-to-end numerics parity (real jax)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire_mode", ["raw", "reduced", "int8"])
def test_streamed_matches_handoff_and_reference(wire_mode):
    """Greedy token streams must be bitwise-identical across the streamed
    transport, the cache handoff, and the hosted single-mesh engine."""
    cfg = small_cfg(layers=2)
    arr = poisson_arrivals(num_devices=2, num_requests=3, arrival_rate=20.0,
                           prompt_len=12, vocab_size=cfg.vocab_size, seed=1)
    streams, sims = {}, {}
    for tp in ("cache_handoff", "streamed"):
        sc = SimConfig(cfg=cfg, mode="split", wire_mode=wire_mode,
                       network="3g", num_devices=2, num_requests=3,
                       arrival_rate=20.0, prompt_len=12, max_new_tokens=3,
                       d_r=16, numerics=True, max_concurrent=2, transport=tp,
                       seed=1, arrivals=arr)
        sims[tp] = Simulation(sc)
        sims[tp].run()
        streams[tp] = {r.uid: list(r.engine_req.generated)
                       for r in sims[tp].requests}
        assert all(len(s) == 3 for s in streams[tp].values())
    assert streams["cache_handoff"] == streams["streamed"]
    runner = sims["streamed"].bank.runner(1)
    eng = runner.make_engine(max_batch=2, max_len=20, seed=0)
    for req in sims["streamed"].requests:
        ref = eng.submit(req.tokens, max_new_tokens=3)
        eng.run()
        assert list(ref.generated) == streams["streamed"][req.uid], wire_mode


def test_engine_single_slot_stream_entry():
    """submit_streamed + stream_step reproduce the engine's own decode for
    one request, and engines of a split share the compiled stream step."""
    from repro.runtime.split_exec import SplitModelBank

    cfg = small_cfg(layers=2)
    bank = SplitModelBank(cfg, 16, seed=0)
    r = bank.runner(1)
    toks = np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=(1, 10)).astype(np.int32)
    payload, scales, c0 = r.edge_half(r.params, toks)
    logits, c1 = r.cloud_half(r.params, payload, scales)

    eng = r.make_engine(max_batch=2, max_len=20, seed=0)
    ref = eng.submit(toks[0], max_new_tokens=4)
    eng.run()

    sreq = eng.submit_streamed(10, logits[0], max_new_tokens=4)
    edge_cache = r.pad_decode_cache(c0, 0, 20)
    cloud_cache = r.pad_decode_cache(c1, 1, 20)
    pos = 10
    while not sreq.done:
        tok = np.asarray([[sreq.generated[-1]]], np.int32)
        row, sc_, edge_cache = r.edge_step(r.params, tok, edge_cache, [pos])
        _, cloud_cache = eng.stream_step(sreq, cloud_cache, row, sc_, pos)
        pos += 1
    assert sreq.generated == ref.generated
    # the jitted stream step is shared across engines of the split
    eng2 = r.make_engine(max_batch=1, max_len=20, seed=0)
    assert eng._stream_step is eng2._stream_step
    # streamed admissions hold no cache-pool slot
    assert eng.num_active == 0


def test_streamed_e2e_numerics_traces():
    cfg = small_cfg(layers=2)
    sc = SimConfig(cfg=cfg, mode="split", wire_mode="int8", network="wifi",
                   num_devices=2, num_requests=4, arrival_rate=20.0,
                   prompt_len=16, max_new_tokens=3, d_r=16, numerics=True,
                   max_concurrent=2, transport="streamed", seed=0)
    sim = Simulation(sc)
    tel = sim.run()
    assert len(tel.traces) == 4
    for t in tel.traces:
        assert t.new_tokens == 3
        assert t.stream_steps == 2
        assert t.downlink_bytes == 3 * TOKEN_BYTES
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)
    assert tel.counters["stream_rows"] == 8
    assert tel.counters["stream_edge_steps"] == 8
    # per-token edge/cloud steps landed in the bank's compile cache
    kinds = {k[0] for k in sim.bank.jit_cache_keys}
    assert {"edge_step", "cloud_step"} <= kinds
    # cloud slots drained; engine pool untouched by streamed requests
    assert sim.server.num_active == 0
    for eng in sim.server._engines.values():
        assert eng.num_active == 0
