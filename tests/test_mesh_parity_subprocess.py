"""Model-parallel split stages on a (pod, model) mesh (DESIGN.md section 11)
must reproduce the replicated pipeline and the single-mesh reference exactly
(greedy): dense and MoE configs, plus the bank's heterogeneous
edge=1/cloud=N halves.  Multi-device, so each test runs in a subprocess with
its own XLA_FLAGS (the main pytest process must stay single-device)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=500)


CODE_PIPELINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro.configs import get_config
from repro.models import model as M
from repro.serving.pipeline import make_split_pipeline

def host(x):
    return np.asarray(jax.device_get(x))

def check(cfg, tag):
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    Mmb, mb, S = 3, 2, 16
    toks = jax.random.randint(jax.random.key(1), (Mmb * mb, S), 0,
                              cfg.vocab_size)
    # replicated 2-pod pipeline (the pre-model-parallel baseline)
    mesh_rep = jax.make_mesh((2, 1), ("pod", "data"))
    rep = host(jax.jit(make_split_pipeline(built, mesh_rep, Mmb, S, mb))(
        params, toks))
    # (pod=2, model=4): stages tensor-parallel within each pod
    mesh_mp = jax.make_mesh((2, 4), ("pod", "model"))
    mp = host(jax.jit(make_split_pipeline(built, mesh_mp, Mmb, S, mb))(
        params, toks))
    # single-mesh reference forward
    ref, _ = M.forward_train(params, built, {"tokens": toks})
    ref = host(ref[:, -1])
    err = float(np.abs(mp - rep).max())
    assert err < 5e-3, (tag, err)
    assert (mp.argmax(-1) == rep.argmax(-1)).all(), \
        (tag, "greedy mismatch vs replicated pipeline")
    assert (mp.argmax(-1) == ref.argmax(-1)).all(), \
        (tag, "greedy mismatch vs single-mesh reference")
    print(tag, "err", err)

dense = get_config("qwen3-8b").reduced().with_butterfly(layer=1, d_r=32)
dense = dataclasses.replace(dense, num_heads=8, num_kv_heads=4)
check(dense, "DENSE")

moe = get_config("qwen3-moe-235b-a22b").reduced()
moe = dataclasses.replace(moe, num_heads=8, num_kv_heads=4)
moe = dataclasses.replace(moe, moe=dataclasses.replace(
    moe.moe, num_experts=4, top_k=2, capacity_factor=100.0, d_ff_expert=128))
moe = moe.with_butterfly(layer=1, d_r=32)
check(moe, "MOE")
print("MESH_PARITY_OK")
"""


def test_pipeline_pod_model_mesh_matches_replicated_and_reference():
    res = _run(CODE_PIPELINE)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MESH_PARITY_OK" in res.stdout


CODE_BANK = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro.configs import get_config
from repro.runtime.split_exec import SplitModelBank

cfg = get_config("qwen3-8b").reduced()
cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4)
bank = SplitModelBank(cfg, d_r=16)
prompt = (np.arange(1, 13, dtype=np.int32) * 7) % cfg.vocab_size

r1 = bank.runner(1)                       # replicated halves
r4 = bank.runner(1, cloud_mp=4)           # heterogeneous: edge=1, cloud=4

# split halves: identical int8 wire, greedy-identical cloud logits
p1, s1, _ = r1.edge_half(r1.params, prompt[None])
p4, s4, _ = r4.edge_half(r4.params, prompt[None])
assert (np.asarray(jax.device_get(p1)) ==
        np.asarray(jax.device_get(p4))).all(), "edge wire codes diverged"
l1, _ = r1.cloud_half(r1.params, p1, s1)
l4, _ = r4.cloud_half(r4.params, p4, s4)
l1, l4 = np.asarray(jax.device_get(l1)), np.asarray(jax.device_get(l4))
assert float(np.abs(l1 - l4).max()) < 5e-3
assert (l1.argmax(-1) == l4.argmax(-1)).all()

# full engine path (prefill + batched decode with in-graph sampling):
# greedy token streams must be bitwise identical across mesh degrees
e1 = r1.make_engine(max_batch=2, max_len=32)
e4 = r4.make_engine(max_batch=2, max_len=32)
q1 = e1.submit(prompt, max_new_tokens=6)
q4 = e4.submit(prompt, max_new_tokens=6)
e1.run(); e4.run()
assert q1.generated == q4.generated, (q1.generated, q4.generated)
print("BANK_HETERO_OK", q1.generated)
"""


def test_bank_heterogeneous_cloud_mp_matches_replicated():
    res = _run(CODE_BANK)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "BANK_HETERO_OK" in res.stdout


CODE_SIM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
from repro.configs import get_config
from repro.runtime.simulator import SimConfig, run_sim, poisson_arrivals

cfg = get_config("qwen3-8b").reduced()
cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4)
arrivals = poisson_arrivals(num_devices=2, num_requests=4, arrival_rate=50.0,
                            prompt_len=12, vocab_size=cfg.vocab_size, seed=3)
base = dict(cfg=cfg, mode="split", num_devices=2, num_requests=4,
            prompt_len=12, max_new_tokens=3, d_r=16, initial_split=1,
            arrivals=arrivals, seed=3)
t_rep = run_sim(SimConfig(**base))
t_mp = run_sim(SimConfig(**base, cloud_mp=4))
toks_rep = [r.new_tokens for r in t_rep.traces]
toks_mp = [r.new_tokens for r in t_mp.traces]
assert toks_rep == toks_mp, (toks_rep, toks_mp)
# the model-parallel cloud is strictly faster on identical arrivals
lat_rep = np.mean([r.latency_s for r in t_rep.traces])
lat_mp = np.mean([r.latency_s for r in t_mp.traces])
assert lat_mp < lat_rep, (lat_mp, lat_rep)
print("SIM_MP_OK", lat_rep, lat_mp)
"""


def test_runtime_sim_cloud_mp_numerics_and_speedup():
    res = _run(CODE_SIM)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SIM_MP_OK" in res.stdout
