"""The paper's deployment, TPU-native: a 2-stage microbatched pipeline over
the ``pod`` mesh axis with the butterfly unit at the stage boundary.

Pod 0 ("edge") computes layers [0, j) + the reduction unit + int8 wire
quantization; a single ``lax.ppermute`` per tick carries ONLY the quantized
codes + f32 scales across the pod boundary (this is the paper's compressed
uplink, visible in the HLO as a collective-permute of an int8 tensor);
pod 1 ("cloud") dequantizes, restores, runs layers [j, N) and the LM head,
and the last-token logits ride the same ppermute back ("the inference
outcome is sent back to the mobile device").

Scope: scoring/prefill pipeline (the paper's single-forward inference),
dense/ssm/hybrid archs; params are replicated within a stage (the edge-side
model is small by construction — that is the paper's point).  Model-parallel
stages and decode pipelining are listed as extensions in DESIGN.md.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.quantization import dequantize, quantize
from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.common import embed, rms_norm, unembed
from repro.models.parallel import LOCAL


def wire_stats(cfg, microbatch: int, seq: int) -> dict:
    """Bytes crossing the pod boundary per microbatch tick."""
    d_r = cfg.butterfly.d_r
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    wire = microbatch * seq * d_r * cfg.butterfly.wire_bits // 8 + \
        microbatch * seq * 4
    raw = microbatch * seq * cfg.d_model * act_bytes
    return {"wire_bytes": wire, "raw_boundary_bytes": raw,
            "compression": raw / wire}


def make_split_pipeline(built: M.BuiltModel, mesh, num_microbatches: int,
                        seq_len: int, microbatch: int,
                        wire_mode: str = "int8"):
    """Returns jit-able ``pipeline_fn(params, tokens) -> last-token logits``.

    tokens: (num_microbatches * microbatch, seq_len) int32, sharded over the
    'data' axis on the batch dim; requires a 'pod' axis of size 2.

    wire_mode — what crosses the pod boundary (the perf-iteration knob):
      "raw"     vanilla collaborative intelligence: the full (mb, S, d_model)
                activation in model dtype (prior work [6]-[12])
      "reduced" butterfly reduction only, no quantization: (mb, S, d_r) dtype
      "int8"    the paper: reduction + int8 wire (codes + f32 scales)
    """
    cfg = built.cfg
    assert built.has_butterfly and len(built.stages) == 2, \
        "pipeline needs a butterfly split (cfg.with_butterfly(...))"
    assert cfg.moe is None, "MoE pipeline stages are a documented extension"
    n_pods = mesh.shape["pod"]
    assert n_pods == 2, "2-stage pipeline: edge pod + cloud pod"
    d_r = cfg.butterfly.d_r
    V = cfg.vocab_size
    d = cfg.d_model
    Mmb = num_microbatches
    dt = jnp.dtype(cfg.dtype)

    assert wire_mode in ("raw", "reduced", "int8"), wire_mode

    def stage_edge(params, toks):
        scale = cfg.arch_type == "dense" and cfg.act == "gelu"
        x = embed(params["embed"], toks, scale=scale)
        x, _, _ = tfm.apply_stage(
            list(built.stages[0]), params["stages"][0], x, cfg=cfg,
            pctx=LOCAL, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        if wire_mode == "raw":
            return x, jnp.zeros((x.shape[0], seq_len, 1), jnp.float32)
        r = x @ params["butterfly"]["w_reduce"]
        if wire_mode == "reduced":
            return r, jnp.zeros((r.shape[0], seq_len, 1), jnp.float32)
        codes, scales = quantize(r, cfg.butterfly.wire_bits)
        return codes, scales

    def stage_cloud(params, codes, scales):
        if wire_mode == "raw":
            x = codes
            x, _, _ = tfm.apply_stage(
                list(built.stages[1]), params["stages"][1], x, cfg=cfg,
                pctx=LOCAL, mode="train", stage_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x)[:, 0]
        r = codes if wire_mode == "reduced" else dequantize(codes, scales, dt)
        x = r @ params["butterfly"]["w_restore"]
        x, _, _ = tfm.apply_stage(
            list(built.stages[1]), params["stages"][1], x, cfg=cfg,
            pctx=LOCAL, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(table, x)[:, 0]                      # (mb, V)

    def shard_body(params, tokens):
        pod = jax.lax.axis_index("pod")
        mb_toks = tokens.reshape(Mmb, -1, seq_len)
        mb = mb_toks.shape[1]

        if wire_mode == "raw":
            wire_shape, wire_dtype = (mb, seq_len, d), dt
        elif wire_mode == "reduced":
            wire_shape, wire_dtype = (mb, seq_len, d_r), dt
        else:
            wire_shape, wire_dtype = (mb, seq_len, d_r), jnp.int8
        zero_wire = (jnp.zeros(wire_shape, wire_dtype),
                     jnp.zeros((mb, seq_len, 1), jnp.float32))
        zero_logits = jnp.zeros((mb, V), jnp.float32)

        def tick(t, carry):
            recv_codes, recv_scales, out, back = carry

            def edge(_):
                i = jnp.clip(t, 0, Mmb - 1)
                toks = jax.lax.dynamic_index_in_dim(mb_toks, i, 0, False)
                codes, scales = stage_edge(params, toks)
                return codes, scales, zero_logits

            def cloud(_):
                logits = stage_cloud(params, recv_codes, recv_scales)
                return zero_wire[0], zero_wire[1], logits

            codes, scales, logits = jax.lax.cond(pod == 0, edge, cloud, None)
            # the wire: int8 codes + scales cross 0 -> 1; logits cross 1 -> 0
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])
            logits_back = jax.lax.ppermute(logits, "pod", [(0, 1), (1, 0)])
            out = jnp.where(t >= 1, out.at[jnp.maximum(t - 1, 0)].set(logits),
                            out)
            back = jnp.where(t >= 1, back.at[jnp.maximum(t - 1, 0)].set(logits_back),
                             back)
            return codes, scales, out, back

        out0 = jnp.zeros((Mmb, mb, V), jnp.float32)
        carry = (*zero_wire, out0, out0)
        *_, out, back = jax.lax.fori_loop(0, Mmb + 1, tick, carry)
        # pod 1 filled `out` locally; pod 0 received `back`. Select the live
        # copy so the caller-visible result is pod-invariant.
        result = jnp.where(pod == 0, back, out)
        return result[None]                                  # add pod dim

    axes = mesh.axis_names
    data_ax = "data" if "data" in axes else None
    fn = compat.shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), P(data_ax, None)),
        out_specs=P("pod", None, data_ax, None),
        check_vma=False,
    )

    def pipeline_fn(params, tokens):
        res = fn(params, tokens)
        return res[0].reshape(-1, V)                         # pod 0's copy

    return pipeline_fn
