"""The Wire: a contended serial mobile/cloud link over the core/wireless
link models.

Any object exposing ``uplink_seconds(nbytes)`` / ``uplink_energy_mj(nbytes)``
(``WirelessNetwork`` from the paper's Table III, or the TPU ``Interconnect``)
backs a :class:`Wire`; link models that also expose ``downlink_seconds`` /
``downlink_energy_mj`` get asymmetric downlink figures, otherwise the
downlink mirrors the uplink.  Each direction is a FIFO pipe: when several
edge devices share it, a transfer waits until the link drains — that
queueing delay is the contention term that only appears at the
request-stream level (JointDNN Sec. V observes the same effect on shared
cellular uplinks).  ``duplex="split"`` (the default, full-duplex radio)
gives each direction its own FIFO; ``duplex="shared"`` makes both
directions contend for one serial frontier (half-duplex).

The downlink carries sampled tokens back to the mobile: one batch of ids at
request completion for the cache-handoff decode transport, one id per
generation step for the streamed transport — which is what makes the
per-token RTT (uplink row + cloud turn + downlink id) a first-class
quantity here (:meth:`Wire.rtt_s`).

Goodput feedback is *windowed*: :meth:`observed_bytes_per_s` reports the
effective rate over the trailing ``window_s`` seconds, so a load transient
that saturated the link stops poisoning the controller's signal once it
drains (the lifetime totals stay in ``stats``/``down_stats`` for
telemetry).  In a multi-cell topology each cell owns its own Wire, so the
contention — and this feedback — is per cell.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple

from repro.core.wireless import get_link
from repro.runtime.tracing import NULL_TRACER


@dataclass
class LinkStats:
    bytes_sent: float = 0.0
    busy_s: float = 0.0               # time the link actually transmitted
    wait_s: float = 0.0               # total contention wait across transfers
    energy_mj: float = 0.0            # mobile radio energy (paper power model)
    n_transfers: int = 0


class Wire:
    """Serial FIFO link pair (uplink + downlink) shared by a fleet of edge
    devices.  ``stats`` accounts the uplink, ``down_stats`` the downlink."""

    def __init__(self, link_model, name: Optional[str] = None,
                 duplex: str = "split", window_s: float = 0.5):
        assert duplex in ("split", "shared"), duplex
        self.model = link_model
        self.name = name or getattr(link_model, "name", "link")
        self.duplex = duplex
        self.window_s = window_s
        self.free_at = 0.0                  # uplink frontier
        self.down_free_at = 0.0             # downlink frontier
        self.stats = LinkStats()
        self.down_stats = LinkStats()
        # trailing-window samples per direction: (done, nbytes, occupied_s)
        self._recent_up: Deque[Tuple[float, float, float]] = deque()
        self._recent_down: Deque[Tuple[float, float, float]] = deque()
        # flight recorder: the simulation swaps in a live tracer and a
        # topology-unique track prefix (wires of different cells can share
        # a link name)
        self.tracer = NULL_TRACER
        self.track_prefix = f"wire/{self.name}"

    @classmethod
    def named(cls, name: str, duplex: str = "split",
              window_s: float = 0.5) -> "Wire":
        return cls(get_link(name), name=name, duplex=duplex,
                   window_s=window_s)

    # ------------------------------------------------------------- faults
    def handover(self, network: str) -> None:
        """Swap the underlying link model mid-run (e.g. 3g → wifi).  Frames
        already admitted keep their old completion times (they were cut at
        the old rate); the goodput windows reset so the controller's next
        decision sees the new link, not a blend."""
        self.model = get_link(network)
        self.name = network
        self._recent_up.clear()
        self._recent_down.clear()

    def blackout(self, now: float, duration: float) -> None:
        """Push both frontiers past a dark window: transfers admitted during
        the blackout start after it lifts.  The fault layer separately
        cancels deliveries already in flight (``cancel_owner``) — those
        frames are lost, not delayed."""
        self.free_at = max(self.free_at, now) + duration
        self.down_free_at = max(self.down_free_at, now) + duration

    # ------------------------------------------------------------- durations
    def transfer_seconds(self, nbytes: float) -> float:
        return self.model.uplink_seconds(nbytes)

    def downlink_seconds(self, nbytes: float) -> float:
        f = getattr(self.model, "downlink_seconds", None)
        return f(nbytes) if f is not None else self.model.uplink_seconds(nbytes)

    def transfer_energy_mj(self, nbytes: float) -> float:
        return self.model.uplink_energy_mj(nbytes)

    def downlink_energy_mj(self, nbytes: float) -> float:
        f = getattr(self.model, "downlink_energy_mj", None)
        return f(nbytes) if f is not None \
            else self.model.uplink_energy_mj(nbytes)

    def rtt_s(self, up_bytes: float, down_bytes: float) -> float:
        """Nominal (contention-free) round trip: ship ``up_bytes`` up and
        ``down_bytes`` back — the streamed transport's per-token wire cost."""
        return self.transfer_seconds(up_bytes) + \
            self.downlink_seconds(down_bytes)

    # ------------------------------------------------------------- transfers
    def transfer(self, nbytes: float, now: float, *,
                 uid: Optional[int] = None,
                 tag: str = "xfer") -> Tuple[float, float]:
        """Enqueue ``nbytes`` on the uplink at virtual time ``now``; returns
        ``(start, done)`` — ``start > now`` means the link was busy.  ``uid``
        and ``tag`` only label the trace span (request id; ``prefill`` /
        ``handoff`` / ``row`` ...)."""
        start = max(now, self.free_at)
        if self.duplex == "shared":
            start = max(start, self.down_free_at)
        dur = self.transfer_seconds(nbytes)
        done = start + dur
        self.free_at = done
        if self.duplex == "shared":
            self.down_free_at = done
        self._account(self.stats, self._recent_up, done, nbytes, dur,
                      start - now, self.transfer_energy_mj(nbytes))
        self._span(f"{self.track_prefix}/up", tag, start, done, uid, nbytes,
                   start - now)
        return start, done

    def transfer_down(self, nbytes: float, now: float, *,
                      uid: Optional[int] = None,
                      tag: str = "xfer") -> Tuple[float, float]:
        """Enqueue ``nbytes`` on the downlink at virtual time ``now``."""
        start = max(now, self.down_free_at)
        if self.duplex == "shared":
            start = max(start, self.free_at)
        dur = self.downlink_seconds(nbytes)
        done = start + dur
        self.down_free_at = done
        if self.duplex == "shared":
            self.free_at = done
        self._account(self.down_stats, self._recent_down, done, nbytes, dur,
                      start - now, self.downlink_energy_mj(nbytes))
        self._span(f"{self.track_prefix}/down", tag, start, done, uid, nbytes,
                   start - now)
        return start, done

    def _span(self, track: str, tag: str, start: float, done: float,
              uid: Optional[int], nbytes: float, wait: float) -> None:
        if not self.tracer.enabled:
            return
        args = {"bytes": nbytes, "wait_ms": wait * 1e3}
        if uid is not None:
            args["uid"] = uid
        self.tracer.complete(track, tag, start, done, cat="wire", args=args)

    @staticmethod
    def _account(s: LinkStats, recent: Deque[Tuple[float, float, float]],
                 done: float, nbytes: float, dur: float, wait: float,
                 energy: float) -> None:
        s.bytes_sent += nbytes
        s.busy_s += dur
        s.wait_s += wait
        s.energy_mj += energy
        s.n_transfers += 1
        recent.append((done, nbytes, dur + wait))

    # ------------------------------------------------------------- occupancy
    def up_backlog_s(self, now: float) -> float:
        """Seconds of queued uplink work ahead of a transfer enqueued *now*
        (0 = idle link) — the metrics sampler's wire-occupancy gauge."""
        return max(0.0, self.free_at - now)

    def down_backlog_s(self, now: float) -> float:
        return max(0.0, self.down_free_at - now)

    # ------------------------------------------------------------- goodput
    def nominal_bytes_per_s(self) -> float:
        return 1.0 / max(self.model.uplink_seconds(1.0), 1e-30)

    def nominal_down_bytes_per_s(self) -> float:
        return 1.0 / max(self.downlink_seconds(1.0), 1e-30)

    def observed_bytes_per_s(self, now: float) -> float:
        """Effective per-request uplink goodput including contention waits
        over the trailing ``window_s`` — what a device experiences *right
        now*, and what the adaptive controller feeds back into the selection
        phase.  A quiet link (no transfers in the window) reads nominal: a
        cleared transient no longer drags a lifetime average behind it."""
        return self._observed(self._recent_up, self.nominal_bytes_per_s(),
                              now)

    def observed_down_bytes_per_s(self, now: float) -> float:
        return self._observed(self._recent_down,
                              self.nominal_down_bytes_per_s(), now)

    def _observed(self, recent: Deque[Tuple[float, float, float]],
                  nominal: float, now: float) -> float:
        horizon = now - self.window_s
        while recent and recent[0][0] < horizon:
            recent.popleft()
        nbytes = sum(b for _, b, _ in recent)
        occupied = sum(o for _, _, o in recent)
        if not recent or occupied <= 0:
            return nominal
        return nbytes / occupied
