"""Wire quantization for the butterfly boundary.

The paper quantizes the FP16 reduced feature tensor to 8 bits *only for the
uplink* (Section III-A); compute stays full precision.  We implement the
same: symmetric absmax int8 per token row (per (batch, position), over the
d_r channel axis), an f32 scale vector rides along (its bytes are counted in
the wire-size accounting — see core/profiler.py).

A straight-through estimator makes the codec differentiable so the butterfly
+ codec train end-to-end, which is the paper's key difference from bolting
JPEG onto a frozen model.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """x: (..., d_r) -> (codes int8/int16, scales f32 (..., 1))."""
    assert bits in (4, 8, 16), bits
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax - 1, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    return codes.astype(dtype), scale


def dequantize(codes: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient."""
    codes, scale = quantize(x, bits)
    return dequantize(codes, scale, x.dtype)


def _fq_fwd(x, bits):
    return fake_quant(x, bits), None


def _fq_bwd(bits, _, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] two-per-byte along the last axis.

    Layout: byte b holds code 2b in its low nibble and code 2b+1 in its
    high nibble, so a ``(..., d)`` tensor packs to ``(..., d // 2)`` int8
    (``d`` must be even).  The nibbles are two's-complement; sign recovery
    happens in :func:`unpack_int4`."""
    assert codes.shape[-1] % 2 == 0, \
        f"int4 packing needs an even last axis, got {codes.shape}"
    lo = codes[..., ::2] & 0x0F
    hi = codes[..., 1::2] & 0x0F
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Invert :func:`pack_int4`: ``(..., d // 2)`` int8 -> ``(..., d)`` int8
    codes in [-8, 7].  Sign-extend each nibble via an arithmetic shift of
    the nibble parked in the high bits."""
    lo = (packed.astype(jnp.int8) << 4) >> 4            # low nibble, signed
    hi = packed.astype(jnp.int8) >> 4                   # high nibble, signed
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,))


def scale_dtype_bytes(dtype=jnp.float32) -> int:
    """Wire width of one per-row scale at its real dtype."""
    return jnp.dtype(dtype).itemsize


def wire_bytes(shape: tuple, bits: int, scale_dtype=jnp.float32) -> int:
    """Bytes on the wire for bit-packed codes + per-row scales.

    Codes pack to ``ceil(n * bits / 8)`` bytes (two int4 codes per byte,
    no silent floor-to-zero for sub-byte wires); scales are counted at
    their real dtype width, one per row."""
    import math
    n = math.prod(shape)
    rows = n // shape[-1]
    return (n * bits + 7) // 8 + rows * scale_dtype_bytes(scale_dtype)
