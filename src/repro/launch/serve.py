"""Serving launcher: batched requests through the ServingEngine (single-mesh
baseline) or the 2-pod split pipeline (--split, the paper's deployment).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --prompts "hello" "world"
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --split
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--prompts", nargs="*", default=["the quick brown fox",
                                                     "once upon a time"])
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--split", action="store_true",
                    help="2-pod split pipeline demo (needs >=2 devices; "
                         "set XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    ap.add_argument("--butterfly-layer", type=int, default=1)
    ap.add_argument("--d-r", type=int, default=32)
    args = ap.parse_args()

    if args.split:
        import os
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=2")

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.data import tokenizer as tok
    from repro.models import model as M

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, vocab_size=max(cfg.vocab_size, tok.VOCAB_SIZE))
    if args.split:
        cfg = cfg.with_butterfly(args.butterfly_layer, args.d_r)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    if args.checkpoint:
        from repro.training.checkpoint import restore_checkpoint
        params, _, meta = restore_checkpoint(args.checkpoint, params)
        print("restored", meta)

    if args.split:
        from repro.serving.pipeline import make_split_pipeline, wire_stats
        mesh = jax.make_mesh((2, 1), ("pod", "data"))
        S = 32
        toks = np.stack([np.resize(tok.encode(p), S) for p in args.prompts])
        Mmb = len(args.prompts)
        pipe = jax.jit(make_split_pipeline(built, mesh, Mmb, S, 1))
        logits = pipe(params, jnp.asarray(toks))
        stats = wire_stats(cfg, 1, S)
        print(f"split pipeline over pod axis: wire={stats['wire_bytes']}B/mb "
              f"raw={stats['raw_boundary_bytes']}B compression={stats['compression']:.1f}x")
        for p, l in zip(args.prompts, logits):
            print(f"  {p!r} -> next-token id {int(jnp.argmax(l))}")
        return

    from repro.serving.engine import ServingEngine
    eng = ServingEngine(params, built, max_batch=max(4, len(args.prompts)),
                        max_len=256)
    reqs = [eng.submit(tok.encode(p), max_new_tokens=args.max_new_tokens,
                       temperature=args.temperature)
            for p in args.prompts]
    eng.run()
    for p, r in zip(args.prompts, reqs):
        print(f"  {p!r} -> {tok.decode(r.generated)!r} (ids {r.generated})")


if __name__ == "__main__":
    main()
