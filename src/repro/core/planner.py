"""Algorithm 1 from the paper: the three-phase DNN partitioning algorithm.

  Training phase  — for each candidate split P_j, find (linear search) the
                    minimal D_r whose end-to-end-trained butterfly model
                    reaches the accuracy target.
  Profiling phase — per split: edge latency/power, uplink time F_j/NB,
                    cloud latency (under load levels K_mobile, K_cloud).
  Selection phase — argmin end-to-end latency or mobile energy.

The training phase takes a callback (train at small scale, or the paper's
published Fig. 7 results); profiling uses core/profiler roofline models or
the paper's published Table IV; selection is exact.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.profiler import HardwareProfile, SplitProfile, profile_split
from repro.core.wireless import NETWORKS, WirelessNetwork

# ---------------------------------------------------------------------------
# training phase
# ---------------------------------------------------------------------------


@dataclass
class TrainingPhaseResult:
    split: int
    d_r: int
    accuracy: float


def training_phase(
    candidate_splits: Sequence[int],
    channel_sizes: Dict[int, int],
    train_and_eval: Callable[[int, int], float],
    accuracy_target: float,
    max_loss: float = 0.02,
    dr_schedule: Optional[Sequence[int]] = None,
) -> List[TrainingPhaseResult]:
    """Paper Algorithm 1 lines 15-25: linear search of minimal D_r per split.

    ``train_and_eval(split, d_r) -> accuracy``; ``channel_sizes[j]`` is C_{P_j}
    (the upper bound of the search).  ``dr_schedule`` optionally thins the
    linear search (the paper sweeps 1..C; we allow 1,2,3,... subsets for
    small-scale runs)."""
    results = []
    floor = accuracy_target - max_loss
    for j in candidate_splits:
        found = None
        grid = dr_schedule if dr_schedule is not None else range(1, channel_sizes[j] + 1)
        for d_r in grid:
            if d_r > channel_sizes[j]:
                break
            acc = train_and_eval(j, d_r)
            if acc >= floor:
                found = TrainingPhaseResult(split=j, d_r=d_r, accuracy=acc)
                break
        if found is None:
            found = TrainingPhaseResult(split=j, d_r=channel_sizes[j],
                                        accuracy=float("nan"))
        results.append(found)
    return results


# ---------------------------------------------------------------------------
# profiling phase
# ---------------------------------------------------------------------------


def profiling_phase(
    trained: Sequence[TrainingPhaseResult],
    split_costs: Callable[[int, int], tuple],
    edge: HardwareProfile,
    cloud: HardwareProfile,
    edge_load: float = 0.0,
    cloud_load: float = 0.0,
) -> List[SplitProfile]:
    """``split_costs(split, d_r) -> (edge_flops, edge_bytes, cloud_flops,
    cloud_bytes, wire_bytes)``."""
    profiles = []
    for t in trained:
        ef, eb, cf, cb, wb = split_costs(t.split, t.d_r)
        profiles.append(profile_split(
            t.split, t.d_r, edge_flops=ef, edge_bytes=eb, cloud_flops=cf,
            cloud_bytes=cb, wire_bytes=wb, edge=edge, cloud=cloud,
            edge_load=edge_load, cloud_load=cloud_load))
    return profiles


# ---------------------------------------------------------------------------
# selection phase
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Selection:
    split: int
    d_r: int
    latency_s: float
    energy_mj: float
    objective: str
    network: str


def selection_phase(profiles: Sequence[SplitProfile],
                    network: WirelessNetwork,
                    objective: str = "latency") -> Selection:
    assert objective in ("latency", "energy")
    key = (lambda p: p.latency(network)) if objective == "latency" else \
        (lambda p: p.mobile_energy_mj(network))
    best = min(profiles, key=key)
    return Selection(split=best.split, d_r=best.d_r,
                     latency_s=best.latency(network),
                     energy_mj=best.mobile_energy_mj(network),
                     objective=objective, network=network.name)


def select_from_table(table: Dict[int, Dict[str, float]],
                      objective: str = "latency") -> int:
    """Selection phase over a published profile table (paper Table IV):
    {split: {latency_ms, energy_mj}} -> chosen split."""
    key = "latency_ms" if objective == "latency" else "energy_mj"
    return min(table, key=lambda j: table[j][key])


# ---------------------------------------------------------------------------
# end-to-end plan for a transformer arch on the pod mesh
# ---------------------------------------------------------------------------


def plan_transformer_split(cfg, seq: int, batch: int, *,
                           edge: HardwareProfile, cloud: HardwareProfile,
                           interconnect, d_r: int,
                           candidate_splits: Optional[Sequence[int]] = None,
                           objective: str = "latency",
                           edge_load: float = 0.0, cloud_load: float = 0.0):
    """Run profiling+selection for a transformer with the butterfly at each
    candidate layer boundary (training phase assumed done / d_r given).

    Returns (Selection-like dict, per-split profile rows)."""
    from repro.core import costs
    from repro.core.butterfly import butterfly_wire_bytes

    n = cfg.num_layers
    splits = list(candidate_splits) if candidate_splits else list(range(1, n))
    rows = []
    act_bytes = 2  # bf16 activations
    for j in splits:
        ef = costs.stack_flops(cfg, seq, 0, j) * batch
        ef += 2 * batch * seq * cfg.d_model * d_r            # reduction unit
        cf = costs.stack_flops(cfg, seq, j, n) * batch
        cf += 2 * batch * seq * d_r * cfg.d_model            # restoration
        cf += costs.embed_flops(cfg, seq) * batch
        eb = ef / max(cfg.d_model, 1)                        # rough bytes proxy
        cb = cf / max(cfg.d_model, 1)
        wire = butterfly_wire_bytes(batch, seq, d_r)
        t_edge = edge.latency_s(ef, eb) / max(1e-9, 1 - edge_load)
        t_cloud = cloud.latency_s(cf, cb) / max(1e-9, 1 - cloud_load)
        t_up = interconnect.uplink_seconds(wire)
        raw_wire = batch * seq * cfg.d_model * act_bytes
        rows.append({
            "split": j, "d_r": d_r, "edge_s": t_edge, "uplink_s": t_up,
            "cloud_s": t_cloud, "latency_s": t_edge + t_up + t_cloud,
            "wire_bytes": wire, "raw_bytes": raw_wire,
            "compression": raw_wire / wire,
            "energy_mj": t_edge * edge.compute_power_w * 1e3 +
                         interconnect.uplink_energy_mj(wire),
        })
    key = "latency_s" if objective == "latency" else "energy_mj"
    best = min(rows, key=lambda r: r[key])
    return best, rows


# ---------------------------------------------------------------------------
# online selection (paper Sec. III-C): re-run the selection phase at runtime
# against *observed* conditions — the split-serving runtime's control law
# ---------------------------------------------------------------------------


# Pluggable selection objectives: each maps the scored (split, transport)
# rows to the winning row.  Registered by name so runtime controllers (and
# the CLI's --objective flag) can pick them without the planner knowing who
# is asking; register_objective() admits project-specific policies.
SELECTION_OBJECTIVES: Dict[str, Callable] = {}


def register_objective(name: str, fn: Callable) -> None:
    """``fn(rows, *, slo_s=None) -> row`` over select_split_online's scored
    rows (each has latency_s / energy_mj / split / transport)."""
    SELECTION_OBJECTIVES[name] = fn


def _objective_latency(rows, *, slo_s=None):
    return min(rows, key=lambda r: r["latency_s"])


def _objective_energy(rows, *, slo_s=None):
    return min(rows, key=lambda r: r["energy_mj"])


def _objective_energy_under_slo(rows, *, slo_s=None):
    """Min mobile energy subject to predicted latency <= SLO.  When no
    candidate meets the SLO the best-effort fallback is the latency winner
    (the least-infeasible pick) rather than an arbitrary energy row."""
    assert slo_s is not None and slo_s > 0, \
        "objective 'energy_under_slo' needs an SLO (--slo-ms)"
    feasible = [r for r in rows if r["latency_s"] <= slo_s]
    if not feasible:
        return _objective_latency(rows)
    return min(feasible, key=lambda r: r["energy_mj"])


register_objective("latency", _objective_latency)
register_objective("energy", _objective_energy)
register_objective("energy_under_slo", _objective_energy_under_slo)


def resolve_objective(name: str) -> Callable:
    try:
        return SELECTION_OBJECTIVES[name]
    except KeyError:
        raise KeyError(f"unknown selection objective {name!r}; known: "
                       f"{sorted(SELECTION_OBJECTIVES)}") from None


def wire_mode_bytes(cfg, seq: int, d_r: int, wire_mode: str,
                    batch: int = 1) -> float:
    """Uplink payload per request for each wire ablation mode.

    "raw"     the boundary activation in model dtype (prior-work CI offload)
    "reduced" butterfly reduction, no wire quantization
    "int8"    the paper: int8 codes + per-row f32 scales
    "int4"    beyond-paper: nibble-packed codes (2/byte) + f32 scales
    "entropy" int8 codes rANS-coded against the learned per-channel prior
              (predicted at the trained-prior nominal rate; the runtime
              charges *actual* coded bytes when real codes exist), raw f32
              scales, plus the per-payload stream overhead.  Never predicted
              worse than int8: the edge ships raw codes when coding would
              expand the payload — which is why single decode rows stay
              fixed-rate int8 (the ~12 B/lane state flush dwarfs them).
    """
    from repro.core import wire_codec
    from repro.core.quantization import wire_bytes

    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    if wire_mode == "raw":
        return float(batch * seq * cfg.d_model * act_bytes)
    if wire_mode == "reduced":
        return float(batch * seq * d_r * act_bytes)
    if wire_mode == "int8":
        return float(wire_bytes((batch, seq, d_r), 8))
    if wire_mode == "int4":
        return float(wire_bytes((batch, seq, d_r), 4))
    if wire_mode == "entropy":
        n = batch * seq * d_r
        coded = wire_codec.predicted_code_bytes(n) \
            + wire_codec.payload_overhead_bytes(d_r)
        return float(min(coded, n) + batch * seq * 4)
    raise ValueError(f"unknown wire_mode {wire_mode!r}")


def select_split_online(cfg, seq: int, d_r: int, *,
                        candidate_splits: Sequence[int],
                        edge: HardwareProfile, cloud: HardwareProfile,
                        link_bytes_per_s: float, cloud_load: float = 0.0,
                        edge_load: float = 0.0, wire_mode: str = "int8",
                        link_energy_mj_per_byte: float = 0.0,
                        handoff_bytes_per_layer: float = 0.0,
                        objective: str = "latency",
                        transports: Sequence[str] = ("cache_handoff",),
                        new_tokens: int = 1,
                        downlink_bytes_per_s: Optional[float] = None,
                        downlink_energy_mj_per_byte: float = 0.0,
                        edge_mp: int = 1, cloud_mp: int = 1,
                        slo_s: Optional[float] = None,
                        pipeline_depth: int = 1):
    """One online iteration of Algorithm 1's selection phase.

    Unlike :func:`plan_transformer_split` this takes the *measured* state the
    runtime's controller observes — effective uplink throughput (nominal
    bandwidth derated by contention) and current server load — and scores
    every hosted partition point against it.  When ``transports`` names more
    than one decode transport, every (split, transport) pair is scored, so
    the controller picks the transport alongside the split:

    * ``cache_handoff`` pays ``handoff_bytes_per_layer`` split-proportional
      extra uplink (the stage-0 KV handoff for multi-token requests), then
      decodes cloud-side and ships all ``new_tokens`` sampled ids down once.
    * ``streamed`` ships only the prefill codes, then pays one wire row up,
      one cloud turn and one id down per generated token — an RTT x tokens
      term against the observed link rates, with uplink bytes flat in the
      prompt length.  With ``pipeline_depth >= 2`` (the decode-pipelined
      mesh: >= 2 in-flight microbatches rotating through the (pod, model)
      pipeline) the per-token cadence is the *slowest stage* — max(edge
      step, wire row + id, cloud step) — instead of their sum, because the
      edge computes microbatch k+1 while the cloud serves microbatch k.
    * ``progressive`` is ``streamed`` with a bitplane-split prefill upload:
      the coarse chunk (high-order planes + scales) ships first, cloud
      prefill starts on it, and the refinement tail of the upload overlaps
      that prefill — TTFT pays max(refine, cloud prefill) instead of their
      sum.  Decode then streams rows exactly like ``streamed``.

    ``objective`` names a registered selection objective
    (:data:`SELECTION_OBJECTIVES`): ``latency``, ``energy``, or
    ``energy_under_slo`` (min energy s.t. predicted latency <= ``slo_s``).

    Returns ``(best_row, rows)``; rows carry a ``transport`` field on top of
    the offline planner's schema."""
    from repro.core import costs

    pick = resolve_objective(objective)
    n = cfg.num_layers
    T = max(int(new_tokens), 1)
    base_wire = wire_mode_bytes(cfg, seq, d_r, wire_mode)
    row_bytes = wire_mode_bytes(cfg, 1, d_r, wire_mode)
    down_bps = downlink_bytes_per_s if downlink_bytes_per_s else float("inf")
    token_down_s = costs.TOKEN_BYTES / down_bps
    link_bps = max(link_bytes_per_s, 1e-9)
    rows = []
    for j in candidate_splits:
        assert 0 < j < n, f"split {j} out of range for {n} layers"
        ef = costs.stack_flops(cfg, seq, 0, j)
        ef += 2 * seq * cfg.d_model * d_r               # reduction unit
        cf = costs.stack_flops(cfg, seq, j, n)
        cf += 2 * seq * d_r * cfg.d_model               # restoration
        cf += costs.embed_flops(cfg, seq)
        eb = ef / max(cfg.d_model, 1)
        cb = cf / max(cfg.d_model, 1)
        # model-parallel stages: each half's compute divides by its degree,
        # matching what the runtime's CostModel charges (DESIGN.md sec. 11)
        ef, eb = costs.model_parallel_share((ef, eb), edge_mp)
        cf, cb = costs.model_parallel_share((cf, cb), cloud_mp)
        t_edge = edge.latency_s(ef, eb) / max(1e-9, 1 - edge_load)
        t_cloud = cloud.latency_s(cf, cb) / max(1e-9, 1 - cloud_load)
        esf, esb = costs.model_parallel_share(
            costs.edge_decode_step_cost(cfg, j, d_r), edge_mp)
        csf, csb = costs.model_parallel_share(
            costs.cloud_decode_step_cost(cfg, j, d_r), cloud_mp)
        t_edge_step = edge.latency_s(esf, esb) / max(1e-9, 1 - edge_load)
        t_cloud_step = cloud.latency_s(csf, csb) / max(1e-9, 1 - cloud_load)
        # a handoff decode turn runs the FULL hosted model cloud-side (the
        # engine's fused edge+wire+cloud step) — split-invariant, and what
        # the runtime's CostModel.decode_step_s actually charges
        hf, hb = costs.model_parallel_share(
            costs.full_decode_step_cost(cfg), cloud_mp)
        t_handoff_step = cloud.latency_s(hf, hb) / max(1e-9, 1 - cloud_load)
        down_bytes = T * costs.TOKEN_BYTES
        for tp in transports:
            if tp == "cache_handoff":
                wire = base_wire + j * handoff_bytes_per_layer
                t_up = wire / link_bps
                edge_total = t_edge
                lat = t_edge + t_up + t_cloud + \
                    (T - 1) * t_handoff_step + down_bytes / down_bps
            elif tp == "streamed":
                wire = base_wire + (T - 1) * row_bytes
                t_up = base_wire / link_bps
                rtt = t_edge_step + row_bytes / link_bps + t_cloud_step + \
                    token_down_s
                if pipeline_depth >= 2:
                    # pipelined decode: stages overlap across microbatches,
                    # so steady state ticks at the slowest stage's rate
                    cadence = max(t_edge_step, t_cloud_step,
                                  row_bytes / link_bps + token_down_s)
                else:
                    cadence = rtt
                edge_total = t_edge + (T - 1) * t_edge_step
                lat = t_edge + t_up + t_cloud + token_down_s + \
                    (T - 1) * cadence
            elif tp == "progressive":
                from repro.core import wire_codec
                scale_bytes = seq * 4
                code_bytes = max(int(base_wire) - scale_bytes, 0)
                coarse, refine = wire_codec.split_coarse_refine(
                    code_bytes, scale_bytes)
                wire = float(coarse + refine) + (T - 1) * row_bytes
                t_up = (coarse + refine) / link_bps
                rtt = t_edge_step + row_bytes / link_bps + t_cloud_step + \
                    token_down_s
                if pipeline_depth >= 2:
                    cadence = max(t_edge_step, t_cloud_step,
                                  row_bytes / link_bps + token_down_s)
                else:
                    cadence = rtt
                edge_total = t_edge + (T - 1) * t_edge_step
                # cloud prefill overlaps the refinement tail of the upload;
                # the first token waits for whichever finishes last
                lat = t_edge + coarse / link_bps + \
                    max(refine / link_bps, t_cloud) + token_down_s + \
                    (T - 1) * cadence
            else:
                raise ValueError(f"unknown transport {tp!r}")
            rows.append({
                "split": j, "transport": tp, "d_r": d_r,
                "edge_s": edge_total, "uplink_s": t_up,
                "cloud_s": t_cloud, "latency_s": lat,
                "wire_bytes": wire, "downlink_bytes": down_bytes,
                "energy_mj": edge_total * edge.compute_power_w * 1e3 +
                             wire * link_energy_mj_per_byte +
                             down_bytes * downlink_energy_mj_per_byte,
            })
    best = pick(rows, slo_s=slo_s)
    return best, rows
