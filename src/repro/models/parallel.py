"""ParallelContext: how model code sees the mesh.

Model code never imports the launcher; it receives a ParallelContext that is
either ``LOCAL`` (single device, tests/benches) or built from the production
mesh (dry-run / train / serve).  MoE uses it for explicit shard_map expert
parallelism; everything else uses GSPMD propagation from the param specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[jax.sharding.Mesh]
    data_axes: Tuple[str, ...] = ("data",)     # ("pod", "data") when multi-pod
    model_axis: str = "model"

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def mp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.model_axis])

    def batch_spec_axes(self):
        """Axes tuple for sharding a batch dim (None when local)."""
        if self.mesh is None:
            return None
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


LOCAL = ParallelContext(mesh=None)


def make_context(mesh: Optional[jax.sharding.Mesh]) -> ParallelContext:
    if mesh is None:
        return LOCAL
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    return ParallelContext(mesh=mesh, data_axes=data_axes, model_axis="model")
