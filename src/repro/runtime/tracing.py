"""Flight-recorder span tracing for the split runtime.

A :class:`Tracer` collects begin/end spans stamped on the *virtual* clock —
one track per edge device, per wire direction, per cloud engine slot, the
cloud accelerator, and per cell controller — and exports them as Chrome
trace-event JSON (load the file in Perfetto / ``chrome://tracing``).  The
runtime emits:

  ``edge/<cell>/dev<N>``   serial device occupancy: ``prefill`` /
                           ``local_infer`` / ``decode_step`` compute spans,
                           ``coalesce`` instant events (numerics batching)
  ``wire/<name>/up|down``  one ``xfer`` span per FIFO transfer (admission
                           wait recorded in ``args.wait_ms``)
  ``cloud/accel``          serial accelerator turns: ``prefill`` /
                           ``decode_turn`` / ``stream_turn``
  ``cloud/slot<N>``        slot residency (``u<uid>`` spans, admission ->
                           release)
  ``ctl/<cell>``           controller decisions as instant events
  ``faults/sched``         injected fault events (``cat="fault"`` instants
                           carrying ``args.kind`` — validated below)
  request-scoped phases    async spans keyed on the request uid
                           (``request`` / ``edge_queue`` / ``uplink_wait`` /
                           ``cloud_queue``) — the span *tree* each thread
                           track's spans nest inside

Determinism: every timestamp is a virtual-clock value and events append in
event-loop order, so a record -> replay pair produces **byte-identical**
trace files (asserted in CI and tests/test_observability.py).  Wall-clock
quantities (jit compile times etc.) never enter a trace — they live in
:mod:`repro.runtime.metrics`.

Tracing is opt-out by default: :data:`NULL_TRACER` swallows every call with
no allocation, and a simulation built without ``trace=True`` runs the exact
pre-tracing path (telemetry-equality regression test).

``python -m repro.runtime.tracing <trace.json>`` validates a trace file
against the trace-event schema (required fields, non-negative durations,
per-track monotonic non-overlapping spans, minimum track-type coverage) —
the CI smoke runs it on every topology trace artifact.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

TRACE_SCHEMA_VERSION = 1

# track-type = first path segment of a track name; the CI smoke requires a
# topology trace to cover at least these four
CORE_TRACK_TYPES = ("edge", "wire", "cloud", "ctl")


def _us(t: float) -> float:
    """Virtual seconds -> trace-event microseconds.  Durations are computed
    as ``_us(t1) - _us(t0)`` so a span's end lands *exactly* on the next
    adjacent span's start (no float re-association drift)."""
    return t * 1e6


class Tracer:
    """Collects trace events on the virtual clock.

    Tracks are registered lazily (:meth:`track`) in first-use order — which
    is event-loop order, hence deterministic — and map to Chrome trace
    ``(pid, tid)`` pairs: one pid per track *type* (``edge``, ``wire``,
    ``cloud``, ``ctl``), one tid per track, both named via metadata events.
    """

    enabled = True

    def __init__(self):
        self.events: List[dict] = []
        self._tracks: Dict[str, Tuple[int, int]] = {}
        self._pids: Dict[str, int] = {}
        self._next_tid = 1

    # ----------------------------------------------------------- track setup
    def track(self, name: str) -> Tuple[int, int]:
        """(pid, tid) of ``name`` (``"<type>/<instance...>"``), registering
        it — and its naming metadata — on first use."""
        if name in self._tracks:
            return self._tracks[name]
        kind = name.split("/", 1)[0]
        if kind not in self._pids:
            pid = len(self._pids) + 1
            self._pids[kind] = pid
            self.events.append({"ph": "M", "name": "process_name",
                                "pid": pid, "tid": 0, "ts": 0.0,
                                "args": {"name": kind}})
        pid = self._pids[kind]
        tid = self._next_tid
        self._next_tid += 1
        self._tracks[name] = (pid, tid)
        self.events.append({"ph": "M", "name": "thread_name",
                            "pid": pid, "tid": tid, "ts": 0.0,
                            "args": {"name": name}})
        return self._tracks[name]

    # ---------------------------------------------------------------- events
    def complete(self, track: str, name: str, t0: float, t1: float, *,
                 cat: str = "span", args: Optional[dict] = None) -> None:
        """One begin/end span ``[t0, t1]`` on a thread track (trace-event
        ``X``).  Thread tracks model serial resources: their spans must not
        overlap (validated by :func:`validate_chrome_trace`)."""
        assert t1 >= t0, (name, t0, t1)
        pid, tid = self.track(track)
        ev = {"ph": "X", "name": name, "cat": cat, "pid": pid, "tid": tid,
              "ts": _us(t0), "dur": _us(t1) - _us(t0)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, track: str, name: str, t: float, *,
                cat: str = "event", args: Optional[dict] = None) -> None:
        """A zero-duration marker (trace-event ``i``, thread scope)."""
        pid, tid = self.track(track)
        ev = {"ph": "i", "name": name, "cat": cat, "pid": pid, "tid": tid,
              "ts": _us(t), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_span(self, track: str, name: str, span_id: int, t0: float,
                   t1: float, *, cat: str = "request",
                   args: Optional[dict] = None) -> None:
        """A begin/end pair on an id-scoped async timeline (trace-event
        ``b``/``e``): request-phase spans that legitimately overlap across
        requests (queues, lifetimes) without breaking the serial-track
        invariant."""
        assert t1 >= t0, (name, t0, t1)
        pid, tid = self.track(track)
        ident = f"0x{span_id:x}"
        b = {"ph": "b", "name": name, "cat": cat, "pid": pid, "tid": tid,
             "ts": _us(t0), "id": ident}
        if args:
            b["args"] = args
        self.events.append(b)
        self.events.append({"ph": "e", "name": name, "cat": cat, "pid": pid,
                            "tid": tid, "ts": _us(t1), "id": ident})

    def counter(self, track: str, name: str, t: float,
                values: Dict[str, float]) -> None:
        """A counter sample (trace-event ``C``) — renders as a stacked
        time-series lane in Perfetto."""
        pid, _ = self.track(track)
        self.events.append({"ph": "C", "name": name, "cat": "metric",
                            "pid": pid, "tid": 0, "ts": _us(t),
                            "args": dict(values)})

    # ---------------------------------------------------------------- export
    def to_json(self) -> str:
        return json.dumps({"displayTimeUnit": "ms",
                           "otherData": {"schema_version":
                                         TRACE_SCHEMA_VERSION},
                           "traceEvents": self.events},
                          indent=1, sort_keys=True)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @property
    def track_names(self) -> List[str]:
        return list(self._tracks)


class _NullTracer(Tracer):
    """Opt-out default: swallows every call, allocates nothing."""

    enabled = False

    def __init__(self):
        self.events = []
        self._tracks = {}

    def track(self, name):                                   # pragma: no cover
        return (0, 0)

    def complete(self, *a, **k):
        pass

    def instant(self, *a, **k):
        pass

    def async_span(self, *a, **k):
        pass

    def counter(self, *a, **k):
        pass


NULL_TRACER = _NullTracer()


# ---------------------------------------------------------------------------
# validation: the CI gate on every trace artifact
# ---------------------------------------------------------------------------


def validate_chrome_trace(doc: dict, *, min_track_types: int = 4,
                          eps_us: float = 1e-6) -> Dict[str, int]:
    """Validate a Chrome trace-event document; raises ``ValueError`` on the
    first violation, returns coverage stats otherwise.

    Checks: the ``traceEvents`` envelope; required fields per phase
    (name/ph/ts/pid/tid, ``dur >= 0`` and a category on ``X`` spans,
    matched ``b``/``e`` pairs per (cat, id, name)); per-track monotonic,
    non-overlapping ``X`` spans (thread tracks are serial resources); and
    at least ``min_track_types`` distinct track types among
    :data:`CORE_TRACK_TYPES`-style prefixes.
    """
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("no traceEvents list")
    tracks: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    names: Dict[Tuple[int, int], str] = {}
    open_async: Dict[Tuple[str, str, str], int] = {}
    counts = {"X": 0, "i": 0, "b": 0, "e": 0, "C": 0, "M": 0}
    for i, ev in enumerate(events):
        for field in ("ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i}: missing {field!r}: {ev}")
        ph = ev["ph"]
        if ph not in counts:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        counts[ph] += 1
        if ph != "M" and "name" not in ev:
            raise ValueError(f"event {i}: missing name: {ev}")
        if ph == "M":
            if ev["name"] == "thread_name":
                names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
            continue
        if "cat" not in ev:
            raise ValueError(f"event {i}: missing cat: {ev}")
        if ev["cat"] == "fault" and "kind" not in ev.get("args", {}):
            raise ValueError(f"event {i}: fault event missing args.kind: "
                             f"{ev}")
        if ph == "X":
            if ev.get("dur", -1) < 0:
                raise ValueError(f"event {i}: X span needs dur >= 0: {ev}")
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                (ev["ts"], ev["ts"] + ev["dur"]))
        elif ph in ("b", "e"):
            key = (ev["cat"], str(ev.get("id")), ev["name"])
            open_async[key] = open_async.get(key, 0) + (1 if ph == "b" else -1)
            if open_async[key] < 0:
                raise ValueError(f"event {i}: async end before begin: {ev}")
    dangling = {k: n for k, n in open_async.items() if n != 0}
    if dangling:
        raise ValueError(f"unmatched async begin/end pairs: {dangling}")
    for key, spans in tracks.items():
        track = names.get(key, str(key))
        last_end = None
        for ts, end in spans:
            if last_end is not None and ts < last_end - eps_us:
                raise ValueError(
                    f"track {track!r}: overlapping/non-monotonic spans "
                    f"(start {ts} < previous end {last_end})")
            last_end = end
    types = {n.split("/", 1)[0] for n in names.values()}
    if len(types) < min_track_types:
        raise ValueError(f"only {sorted(types)} track types present; "
                         f"need >= {min_track_types}")
    return {"events": len(events), "tracks": len(names),
            "track_types": len(types), **counts}


def main(argv=None) -> None:                                 # pragma: no cover
    import argparse
    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON file")
    ap.add_argument("trace")
    ap.add_argument("--min-track-types", type=int, default=4)
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    stats = validate_chrome_trace(doc,
                                  min_track_types=args.min_track_types)
    print(f"{args.trace}: OK — {stats['events']} events on "
          f"{stats['tracks']} tracks ({stats['track_types']} track types; "
          f"{stats['X']} spans, {stats['i']} instants, "
          f"{stats['b']} async, {stats['C']} counters)")


if __name__ == "__main__":                                   # pragma: no cover
    main()
