# Pallas TPU kernels for the compute hot-spots this system optimizes:
#   butterfly_kernel  fused reduction-projection + int8 wire quantization
#                     (the paper's edge-side hot path) and its mirror
#   flash_attention   blockwise-softmax GQA attention (causal/sliding window)
#   rmsnorm           fused row-tiled RMSNorm
# ops.py = jit'd wrappers (interpret mode on CPU); ref.py = jnp oracles.
