"""The split-serving simulation: fleet + wire + server + controller.

A fleet of edge devices emits Poisson request streams; each request runs the
edge half of the current partition point, contends for the shared uplink,
and is served by the cloud's continuous-batching engine.  All timing is
virtual (deterministic for a fixed seed); numerics are real jax when
``numerics=True`` and skipped entirely in timing-only mode (used by the
fast benchmark sweeps and scheduler tests).

Serving modes:
  "split"  the paper: edge layers + butterfly reduce/quantize, compressed wire
  "cloud"  cloud-only offload: raw input features cross the wire
  "edge"   mobile-only: everything on the device, nothing crosses

Decode transports (split mode, multi-token requests — runtime/transports.py):
  "cache_handoff"  ship the edge stage-0 KV cache up; decode cloud-side
  "streamed"       edge keeps its cache; one (1, d_r) row up + one id down
                   per generated token
  "auto"           the adaptive controller picks per request, alongside the
                   split (requires adapt=True)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.profiler import GTX_1080TI, JETSON_TX2, HardwareProfile
from repro.runtime.actors import CloudServer, EdgeDevice, SimRequest
from repro.runtime.clock import EventLoop
from repro.runtime.split_exec import CostModel, SplitModelBank
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.wire import Wire


def ramp_load(t0: float, t1: float, l0: float = 0.0,
              l1: float = 0.95) -> Callable[[float], float]:
    """Background cloud load ramping linearly from l0@t0 to l1@t1."""
    def f(t: float) -> float:
        if t <= t0:
            return l0
        if t >= t1:
            return l1
        return l0 + (l1 - l0) * (t - t0) / (t1 - t0)
    return f


@dataclass(frozen=True)
class Arrival:
    """One request of a pre-built arrival trace."""
    device: int
    t: float
    tokens: Optional[np.ndarray] = None      # prompt ids (numerics mode)


def poisson_arrivals(*, num_devices: int, num_requests: int,
                     arrival_rate: float, prompt_len: int,
                     vocab_size: Optional[int] = None,
                     seed: int = 0) -> List[Arrival]:
    """THE arrival-trace builder (shared by the simulator, the CLI and
    ``benchmarks.run runtime``): deterministic per-device Poisson
    inter-arrivals, plus prompt tokens when ``vocab_size`` is given.
    Building the trace once and passing it through ``SimConfig.arrivals``
    guarantees mode/wire/transport comparisons run the identical trace."""
    out: List[Arrival] = []
    per_dev = [num_requests // num_devices] * num_devices
    for i in range(num_requests % num_devices):
        per_dev[i] += 1
    for dev, n in enumerate(per_dev):
        rng = np.random.default_rng([seed, dev])
        t = 0.0
        for _ in range(n):
            t += rng.exponential(1.0 / arrival_rate)
            tokens = None
            if vocab_size:
                tokens = rng.integers(0, vocab_size, size=(prompt_len,),
                                      dtype=np.int64).astype(np.int32)
            out.append(Arrival(dev, t, tokens))
    return out


@dataclass
class SimConfig:
    cfg: object                              # ModelConfig (butterfly optional)
    mode: str = "split"                      # split | cloud | edge
    wire_mode: str = "int8"                  # raw | reduced | int8
    transport: str = "cache_handoff"         # cache_handoff | streamed | auto
    network: str = "3g"                      # 3g | 4g | wifi | inter_pod
    duplex: str = "split"                    # split | shared downlink FIFO
    num_devices: int = 4
    num_requests: int = 16
    arrival_rate: float = 20.0               # per device, requests/s
    prompt_len: int = 32
    max_new_tokens: int = 4
    d_r: int = 16
    initial_split: int = 1
    candidate_splits: Optional[Sequence[int]] = None
    edge: HardwareProfile = JETSON_TX2
    cloud: HardwareProfile = GTX_1080TI
    # model-axis degree of each half's stage (DESIGN.md section 11): timing
    # divides by the degree, and in numerics mode the bank's jitted halves
    # really run shard_map'd over that many local devices (heterogeneous
    # edge=1 / cloud=N is the expected shape)
    edge_mp: int = 1
    cloud_mp: int = 1
    background_load: Optional[Callable[[float], float]] = None
    adapt: bool = False
    control_interval_s: float = 0.05
    max_concurrent: int = 8
    seed: int = 0
    numerics: bool = True
    arrivals: Optional[Sequence[Arrival]] = None   # overrides Poisson build


class Simulation:
    def __init__(self, sim_cfg: SimConfig):
        c = sim_cfg
        assert c.mode in ("split", "cloud", "edge"), c.mode
        assert c.transport in ("cache_handoff", "streamed", "auto"), \
            c.transport
        if c.transport == "auto":
            assert c.adapt and c.mode == "split", \
                "transport='auto' needs the adaptive controller (split mode)"
        base = c.cfg
        if base.butterfly is not None:
            base = replace(base, butterfly=None)
        self.sim_cfg = c
        self.base_cfg = base
        self.loop = EventLoop()
        self.telemetry = Telemetry()
        self.uplink = Wire.named(c.network, duplex=c.duplex)
        self.current_split = c.initial_split
        self.current_transport = "cache_handoff" if c.transport == "auto" \
            else c.transport
        self.candidates = list(c.candidate_splits) if c.candidate_splits \
            else list(range(1, base.num_layers))
        assert c.initial_split in self.candidates, \
            f"initial split {c.initial_split} not in {self.candidates}"
        self.bank = SplitModelBank(base, c.d_r, wire_mode=c.wire_mode,
                                   seed=c.seed, edge_mp=c.edge_mp,
                                   cloud_mp=c.cloud_mp) if c.numerics else None
        self.cost = CostModel(base, c.edge, c.cloud, edge_mp=c.edge_mp,
                              cloud_mp=c.cloud_mp)
        self._remaining = c.num_requests
        self.server = CloudServer(
            loop=self.loop, cost=self.cost, bank=self.bank, mode=c.mode,
            d_r=c.d_r, telemetry=self.telemetry,
            max_concurrent=c.max_concurrent,
            background_load=c.background_load,
            engine_seed=c.seed,
            max_len=c.prompt_len + c.max_new_tokens + 2,
            on_done=self._on_done, numerics_split=c.initial_split,
            wire=self.uplink)
        self.devices = [
            EdgeDevice(i, loop=self.loop, cost=self.cost, uplink=self.uplink,
                       server=self.server, bank=self.bank, mode=c.mode,
                       wire_mode=c.wire_mode, d_r=c.d_r,
                       telemetry=self.telemetry,
                       numerics_split=c.initial_split)
            for i in range(c.num_devices)]
        self.server.devices = self.devices       # downlink delivery targets
        self.controller: Optional[object] = None
        if c.adapt and c.mode == "split":
            from repro.runtime.controller import AdaptiveSplitController
            self.controller = AdaptiveSplitController(
                loop=self.loop, uplink=self.uplink,
                cloud_load=self.server.current_load,
                cfg=base, d_r=c.d_r, seq=c.prompt_len,
                candidate_splits=self.candidates,
                edge=c.edge, cloud=c.cloud, wire_mode=c.wire_mode,
                telemetry=self.telemetry,
                set_split=self._set_split, get_split=lambda: self.current_split,
                interval_s=c.control_interval_s,
                handoff_bytes_per_layer=(
                    self.cost.stage0_cache_bytes(c.prompt_len, 1)
                    if c.max_new_tokens > 1 else 0.0),
                transport_mode=c.transport,
                new_tokens=c.max_new_tokens,
                set_transport=self._set_transport,
                get_transport=lambda: self.current_transport,
                edge_mp=c.edge_mp, cloud_mp=c.cloud_mp)

    # ------------------------------------------------------------------ api
    def run(self) -> Telemetry:
        self._schedule_arrivals()
        if self.controller is not None:
            self.controller.start()
        self.loop.run()
        assert self._remaining == 0, \
            f"{self._remaining} requests never completed"
        if self.bank is not None:
            c = self.telemetry.counters
            c["engine_decode_steps"] = sum(
                e.decode_steps for e in self.server._engines.values()) + sum(
                d._local_engine.decode_steps for d in self.devices
                if d._local_engine is not None)
            c["bank_jit_cache_entries"] = self.bank.jit_cache_entries
        return self.telemetry

    # ------------------------------------------------------------- internals
    def _set_split(self, split: int) -> None:
        self.current_split = split

    def _set_transport(self, transport: str) -> None:
        self.current_transport = transport

    def _on_done(self, req: SimRequest) -> None:
        self._remaining -= 1
        if self._remaining == 0 and self.controller is not None:
            self.controller.stop()

    def _schedule_arrivals(self) -> None:
        c = self.sim_cfg
        arrivals = c.arrivals if c.arrivals is not None else poisson_arrivals(
            num_devices=c.num_devices, num_requests=c.num_requests,
            arrival_rate=c.arrival_rate, prompt_len=c.prompt_len,
            vocab_size=self.base_cfg.vocab_size if c.numerics else None,
            seed=c.seed)
        self._remaining = len(arrivals)
        self.requests: List[SimRequest] = []
        for uid, a in enumerate(arrivals):
            assert not c.numerics or a.tokens is not None, \
                "numerics mode needs prompt tokens in the arrival trace"
            trace = RequestTrace(
                uid=uid, device=a.device, mode=c.mode, wire_mode=c.wire_mode,
                split=0, prompt_len=c.prompt_len)
            req = SimRequest(trace=trace, tokens=a.tokens,
                             max_new_tokens=c.max_new_tokens)
            self.requests.append(req)
            self.loop.schedule_at(a.t, self._make_arrival(a.device, req))

    def _make_arrival(self, dev: int, req: SimRequest) -> Callable[[], None]:
        def fire() -> None:
            # split and transport are pinned when the mobile starts the
            # request — the controller's latest decision governs new
            # arrivals only
            if self.sim_cfg.mode == "split":
                req.trace.split = self.current_split
                req.trace.transport = self.current_transport
            elif self.sim_cfg.mode == "edge":
                req.trace.split = self.base_cfg.num_layers
            else:
                req.trace.split = 0
            self.devices[dev].on_arrival(req)
        return fire


def run_sim(sim_cfg: SimConfig) -> Telemetry:
    return Simulation(sim_cfg).run()
