"""Split-serving runtime simulator CLI.

Streams Poisson requests from a fleet of simulated edge devices through the
butterfly split (edge half -> contended wireless uplink -> cloud
continuous-batching server) on a deterministic virtual clock, and prints the
per-request latency breakdown plus p50/p95/p99 aggregates.

Multi-cell topologies put heterogeneous fleets behind per-cell radios
(``--topology 3g:4xphone,wifi:2xjetson``): each cell gets its own Wire and
its own adaptive controller, all contending for one cloud.  Any run's
arrival stream can be recorded to JSONL (``--record-trace``) and replayed
byte-for-byte (``--replay-trace``).

Examples:
  PYTHONPATH=src python -m repro.launch.runtime_sim --network 3g --devices 4 --requests 16
  PYTHONPATH=src python -m repro.launch.runtime_sim --mode cloud --network 3g
  PYTHONPATH=src python -m repro.launch.runtime_sim --wire-mode raw --no-numerics
  PYTHONPATH=src python -m repro.launch.runtime_sim --transport streamed \\
      --seq 128 --max-new-tokens 16 --no-numerics
  PYTHONPATH=src python -m repro.launch.runtime_sim --adapt --load-ramp 0:0,0.3:0.97 \\
      --requests 64 --rate 40 --max-new-tokens 1 --no-numerics
  PYTHONPATH=src python -m repro.launch.runtime_sim --topology 3g:4xjetson,wifi:4xphone \\
      --adapt --transport auto --load-ramp 0:0.95 --no-numerics \\
      --record-trace trace.jsonl
  PYTHONPATH=src python -m repro.launch.runtime_sim --topology 3g:4xjetson,wifi:4xphone \\
      --adapt --transport auto --load-ramp 0:0.95 --no-numerics \\
      --replay-trace trace.jsonl
  PYTHONPATH=src python -m repro.launch.runtime_sim --adapt \\
      --objective energy_under_slo --slo-ms 50 --no-numerics
"""
from __future__ import annotations

import argparse
import dataclasses
import json


def parse_ramp(spec: str):
    """"t0:l0,t1:l1" -> piecewise-linear background-load schedule."""
    pts = []
    try:
        for part in spec.split(","):
            t, l = part.split(":")
            pts.append((float(t), float(l)))
    except ValueError:
        raise SystemExit(f"--load-ramp: expected 't0:l0,t1:l1,...', "
                         f"got {spec!r}")
    pts.sort()

    def f(t: float) -> float:
        if t <= pts[0][0]:
            return pts[0][1]
        for (t0, l0), (t1, l1) in zip(pts, pts[1:]):
            if t <= t1:
                return l0 + (l1 - l0) * (t - t0) / max(t1 - t0, 1e-12)
        return pts[-1][1]
    return f


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--layers", type=int, default=4,
                    help="override layer count of the reduced arch "
                         "(>=2; more layers = more candidate splits)")
    ap.add_argument("--heads", type=int, default=None,
                    help="override attention head count of the reduced arch "
                         "(model-parallel degrees must divide the heads)")
    ap.add_argument("--kv-heads", type=int, default=None,
                    help="override kv head count of the reduced arch")
    ap.add_argument("--mode", choices=("split", "cloud", "edge"),
                    default="split")
    ap.add_argument("--wire-mode",
                    choices=("raw", "reduced", "int8", "int4", "entropy"),
                    default="int8",
                    help="entropy = int8 codes rANS-coded against the "
                         "learned per-channel prior (core/wire_codec; "
                         "lossless, so numerics match int8 bitwise); "
                         "payload bytes become data-dependent and telemetry "
                         "gains coded_bytes/compression_ratio")
    ap.add_argument("--transport",
                    choices=("cache_handoff", "streamed", "progressive",
                             "auto"),
                    default="cache_handoff",
                    help="decode transport for multi-token split requests: "
                         "cache_handoff ships the edge stage-0 KV cache up "
                         "front; streamed keeps it on the edge and sends one "
                         "int8 (1, d_r) row per generated token (DESIGN.md "
                         "section 8.6); progressive is streamed with a "
                         "bitplane-split prefill upload (cloud prefill "
                         "starts on the coarse planes and overlaps the "
                         "refinement tail, DESIGN.md section 18); auto lets "
                         "each cell's adaptive controller pick per request "
                         "(requires --adapt)")
    ap.add_argument("--network", default="3g",
                    choices=("3g", "4g", "wifi", "inter_pod"))
    ap.add_argument("--duplex", choices=("split", "shared"), default="split",
                    help="uplink/downlink FIFO contention: independent per "
                         "direction (split) or one serial frontier (shared)")
    ap.add_argument("--topology", default=None,
                    help="multi-cell topology 'net[/duplex]:<N>x<class>"
                         "[@rate],...' (e.g. '3g:4xphone,wifi:2xjetson'; "
                         "classes: core/profiler.DEVICE_CLASSES); each cell "
                         "gets its own Wire + adaptive controller and "
                         "overrides --network/--duplex/--devices "
                         "(DESIGN.md section 12)")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16,
                    help="total requests across all cells")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate per device (req/s)")
    ap.add_argument("--workload", default=None, metavar="SPEC",
                    help="workload spec '<kind>:key=value,...' (kinds: "
                         "poisson | pareto | diurnal | flash; e.g. "
                         "'pareto:alpha=1.5,rate=20,n=1000,"
                         "interactive=0.25'); its rate/n/prompt_len "
                         "override --rate/--requests/--seq "
                         "(DESIGN.md section 17)")
    ap.add_argument("--gateway", default=None, metavar="SPEC",
                    help="serving-gateway policy: comma list of "
                         "priority | shed | breaker | hedge[=delay_s] | "
                         "autoscale | slo=<int_ms>/<batch_ms|inf> | "
                         "reserve=<n> | cache=<n> | replicas=<n> | "
                         "spinup=<s> (DESIGN.md section 17; autoscale "
                         "needs --no-numerics)")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--d-r", type=int, default=16)
    ap.add_argument("--split", type=int, default=1,
                    help="initial partition point (layers on the edge)")
    ap.add_argument("--adapt", action="store_true",
                    help="enable the adaptive split controller (Sec. III-C); "
                         "topologies run one controller per cell")
    ap.add_argument("--objective", default="latency",
                    help="controller selection objective "
                         "(core/planner.SELECTION_OBJECTIVES): latency | "
                         "energy | energy_under_slo (needs --slo-ms)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency SLO for --objective energy_under_slo")
    ap.add_argument("--control-interval", type=float, default=0.05)
    ap.add_argument("--load-ramp", default=None,
                    help='background cloud load "t0:l0,t1:l1,..."')
    ap.add_argument("--cloud-x", type=float, default=None,
                    help="cloud speed as a multiple of the edge platform "
                         "(default: paper's TX2 -> 1080Ti pairing)")
    ap.add_argument("--edge-mp", type=int, default=1,
                    help="model-axis degree of the edge half's stage "
                         "(DESIGN.md section 11; timing divides by it, and "
                         "with numerics the half runs shard_map'd over that "
                         "many local devices)")
    ap.add_argument("--cloud-mp", type=int, default=1,
                    help="model-axis degree of the cloud half's stage "
                         "(heterogeneous edge=1 cloud=N is the expected "
                         "shape; numerics needs that many local devices)")
    ap.add_argument("--max-concurrent", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-numerics", action="store_true",
                    help="timing-only (skip the real jax computation)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="inject a fault schedule: comma-separated "
                         "'kind@t[:arg][+dur]' events (leave@0.05:2, "
                         "join@0.2:<cell>, handover@0.1:<cell>><net>, "
                         "blackout@0.15:<cell>+0.05, outage@0.3+0.2) or "
                         "'random:<seed>' for a seeded chaos schedule over "
                         "the parsed topology (DESIGN.md section 15)")
    ap.add_argument("--record-trace", default=None, metavar="JSONL",
                    help="record this run's arrival stream (cell, device, t, "
                         "prompt) for later --replay-trace")
    ap.add_argument("--replay-trace", default=None, metavar="JSONL",
                    help="replay a recorded arrival stream instead of "
                         "building Poisson arrivals (byte-for-byte "
                         "reproducible; overrides --requests/--rate)")
    ap.add_argument("--json", default=None, help="write full trace JSON here")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="write a Chrome trace-event file of the run "
                         "(virtual-clock spans; load in Perfetto / "
                         "chrome://tracing; validate with "
                         "'python -m repro.runtime.tracing <file>')")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="write the fixed-interval metrics timeline (queue "
                         "depths, wire occupancy/goodput, cloud batch, "
                         "per-cell in-flight) as JSONL")
    ap.add_argument("--metrics-interval", type=float, default=0.01,
                    help="sampler period in virtual seconds")
    ap.add_argument("--profile-jit", action="store_true",
                    help="wall-clock compile-vs-execute attribution per jit "
                         "cache entry (numerics mode; host-dependent, so "
                         "excluded from virtual-clock artifacts)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.profiler import GTX_1080TI, JETSON_TX2
    from repro.runtime.faults import FaultSchedule
    from repro.runtime.simulator import (SimConfig, Simulation,
                                         parse_topology, trace_arrivals,
                                         trace_faults)

    cfg = get_config(args.arch).reduced()
    if args.layers and args.layers != cfg.num_layers:
        cfg = dataclasses.replace(cfg, num_layers=max(2, args.layers))
    if args.heads:
        cfg = dataclasses.replace(cfg, num_heads=args.heads)
    if args.kv_heads:
        cfg = dataclasses.replace(cfg, num_kv_heads=args.kv_heads)
    edge = JETSON_TX2
    cloud = edge.scaled(args.cloud_x, "cloud_slice") if args.cloud_x \
        else GTX_1080TI
    topology = parse_topology(args.topology) if args.topology else None
    arrivals = None
    faults = None
    if args.replay_trace:
        arrivals = trace_arrivals(args.replay_trace)
        faults = trace_faults(args.replay_trace)
    if args.faults:
        if args.faults.startswith("random:"):
            seed = int(args.faults.split(":", 1)[1])
            cells = tuple(c.name for c in topology) if topology \
                else ("cell0",)
            n_dev = sum(c.num_devices for c in topology) if topology \
                else args.devices
            faults = FaultSchedule.random(seed, cells=cells,
                                          num_devices=n_dev)
        else:
            faults = FaultSchedule.parse(args.faults)
    sim_cfg = SimConfig(
        cfg=cfg, mode=args.mode, wire_mode=args.wire_mode,
        transport=args.transport, network=args.network, duplex=args.duplex,
        topology=topology, num_devices=args.devices,
        num_requests=args.requests, arrival_rate=args.rate,
        prompt_len=args.seq, max_new_tokens=args.max_new_tokens,
        d_r=args.d_r, initial_split=args.split,
        edge=edge, cloud=cloud,
        edge_mp=args.edge_mp, cloud_mp=args.cloud_mp,
        background_load=parse_ramp(args.load_ramp) if args.load_ramp else None,
        adapt=args.adapt, control_interval_s=args.control_interval,
        objective=args.objective, slo_ms=args.slo_ms,
        max_concurrent=args.max_concurrent, seed=args.seed,
        numerics=not args.no_numerics, arrivals=arrivals, faults=faults,
        workload=args.workload, gateway=args.gateway,
        trace=bool(args.trace_out), metrics=bool(args.metrics_out),
        metrics_interval_s=args.metrics_interval,
        profile_jit=args.profile_jit)

    sim = Simulation(sim_cfg)
    if args.record_trace:
        sim.record_trace(args.record_trace)
        print(f"# recorded {len(sim.arrivals)} arrivals -> "
              f"{args.record_trace}")
    tel = sim.run()

    mp_note = ""
    if args.edge_mp > 1 or args.cloud_mp > 1:
        mp_note = f", model-parallel edge x{args.edge_mp} / " \
                  f"cloud x{args.cloud_mp}"
    fleet_note = args.topology if args.topology else \
        f"{args.devices} devices on {args.network}"
    print(f"# {args.mode} serving, wire={args.wire_mode}, "
          f"transport={args.transport}, {fleet_note}, "
          f"{len(sim.arrivals)} requests, "
          f"arch={cfg.name} ({cfg.num_layers} layers, d_r={args.d_r})"
          f"{mp_note}")
    print(tel.table())
    s = tel.summary()
    print(f"\nlatency  p50 {s['latency_p50_ms']:9.2f} ms   "
          f"p95 {s['latency_p95_ms']:9.2f} ms   "
          f"p99 {s['latency_p99_ms']:9.2f} ms")
    print(f"ttft     p50 {s['ttft_p50_ms']:9.2f} ms   "
          f"mean wire {s['mean_wire_kb']:8.2f} kB   "
          f"mean mobile energy {s['mean_mobile_energy_mj']:8.1f} mJ")
    for cell in sim.cells:
        w = cell.wire
        print(f"[{cell.name}] uplink busy {w.stats.busy_s*1e3:.1f} ms, "
              f"wait {w.stats.wait_s*1e3:.1f} ms over "
              f"{w.stats.n_transfers} transfers; "
              f"downlink busy {w.down_stats.busy_s*1e3:.1f} ms, "
              f"wait {w.down_stats.wait_s*1e3:.1f} ms "
              f"({w.down_stats.bytes_sent:.0f} B of sampled ids)")
    if len(sim.cells) > 1:
        fair = tel.fairness()
        print(f"fairness: max/min mean latency "
              f"{fair['max_min_latency_ratio']:.2f}x, p95 spread "
              f"{fair['p95_spread_ms']:.2f} ms, Jain "
              f"{fair['jain_index']:.3f}")
        for name, row in tel.cell_summary().items():
            print(f"  [{name}] n={row['n_requests']:.0f} "
                  f"p50 {row['latency_p50_ms']:.2f} ms  "
                  f"p95 {row['latency_p95_ms']:.2f} ms  "
                  f"uplink wait {row['mean_uplink_wait_ms']:.2f} ms  "
                  f"energy {row['mean_mobile_energy_mj']:.1f} mJ")
    if s["mean_stream_rtt_ms"] > 0:
        print(f"streamed decode: mean per-token RTT "
              f"{s['mean_stream_rtt_ms']:.2f} ms "
              f"(row up + cloud turn + id down)")
    if sim.injector is not None:
        print(f"\nfaults ({len(sim.fault_schedule)} injected): "
              f"availability {s['availability_pct']:.1f}%  "
              f"done {s['n_done']:.0f}  failed {s['n_failed']:.0f}  "
              f"migrated {s['n_migrated']:.0f}  "
              f"retried {s['n_retried']:.0f}  "
              f"edge-fallback {s['n_fallback']:.0f}")
        for ev in sim.fault_schedule:
            tgt = ev.cell or (f"dev{ev.device}" if ev.device >= 0 else "cloud")
            extra = f" -> {ev.network}" if ev.network else ""
            extra += f" for {ev.duration*1e3:.0f} ms" if ev.duration else ""
            print(f"  {ev.t:7.3f}s  {ev.kind:<13} {tgt}{extra}")
    if sim.gateway is not None:
        c = tel.counters
        print(f"\ngateway ({args.gateway}): done {s['n_done']:.0f}  "
              f"failed {s['n_failed']:.0f}  shed {s['n_shed']:.0f}  "
              f"hedged {s['n_hedged']:.0f}  "
              f"cache hits {c['gateway_cache_hits']:.0f}  "
              f"breaker opens {c['gateway_breaker_opens']:.0f}  "
              f"scale-ups {c['gateway_scale_ups']:.0f}")
        for cls, row in tel.class_summary().items():
            print(f"  [{cls:<11}] n={row['n_requests']:.0f} "
                  f"done {row['n_done']:.0f} shed {row['n_shed']:.0f}  "
                  f"p50 {row['latency_p50_ms']:.2f} ms  "
                  f"p99 {row['latency_p99_ms']:.2f} ms")
    if tel.decisions:
        print("\ncontroller decisions (t, cell, cloud_load, split, "
              "transport):")
        for d in tel.decisions:
            mark = " <-- moved" if d.new_split != d.old_split else ""
            print(f"  {d.t:7.3f}s  [{d.cell}]  load={d.cloud_load:5.1%}  "
                  f"split={d.new_split}  {d.transport}{mark}")
    if args.profile_jit and tel.jit_profile:
        h = tel.jit_profile["headline"]
        print(f"\njit profile: {h['entries']} cache entries, "
              f"{h['calls']} dispatches, compile "
              f"{h['compile_wall_ms']:.1f} ms / steady "
              f"{h['steady_wall_ms']:.1f} ms "
              f"(compile fraction {h['compile_fraction']:.1%})")
        for key, row in sorted(tel.jit_profile["entries"].items()):
            print(f"  {key:<28} first {row['first_call_ms']:8.1f} ms  "
                  f"steady x{row['steady_calls']:<3.0f} "
                  f"mean {row['steady_mean_ms']:7.2f} ms")
    if args.json:
        with open(args.json, "w") as f:
            f.write(tel.to_json())
        print(f"\nwrote {args.json}")
    if args.trace_out:
        sim.tracer.write(args.trace_out)
        print(f"wrote {args.trace_out} "
              f"({len(sim.tracer.events)} trace events; validate with "
              f"'python -m repro.runtime.tracing {args.trace_out}')")
    if args.metrics_out:
        sim.sampler.write(args.metrics_out)
        print(f"wrote {args.metrics_out} "
              f"({len(sim.sampler.rows)} samples x "
              f"{len(sim.sampler.sources)} sources)")


if __name__ == "__main__":
    main()
