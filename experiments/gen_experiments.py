"""Fill EXPERIMENTS.md's generated-table markers from experiments/dryrun/*.json.

Usage: PYTHONPATH=src python experiments/gen_experiments.py
Replaces <!-- DRYRUN_TABLE -->, <!-- ROOFLINE_TABLE -->, <!-- PERF_LOG -->,
<!-- WIRE_TABLE --> sections in place (idempotent: content lives between the
marker and the next heading).
"""
from __future__ import annotations

import io
import json
import os
import re
import sys

sys.path.insert(0, os.path.dirname(__file__))
from aggregate import ARCH_ORDER, SHAPE_ORDER, fmt_bytes, load  # noqa: E402

D = os.path.join(os.path.dirname(__file__), "dryrun")
MD = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def get(recs, arch, shape, mesh, tag=""):
    lst = recs.get((arch, shape, mesh), [])
    want = f"{arch}_{shape}_{mesh.replace('x', '-')}{('_' + tag) if tag else ''}.json"
    for f, r in lst:
        if f == want:
            return r
    return None


def dryrun_table(recs):
    out = io.StringIO()
    print("| arch | shape | 16x16 (single-pod, exact costs) | 2x16x16 (multi-pod, compile-proof) |", file=out)
    print("|---|---|---|---|", file=out)
    n_ok = n_skip = 0
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cells = []
            for mesh in ("16x16", "2x16x16"):
                r = get(recs, a, s, mesh)
                if r is None:
                    cells.append("(missing)")
                elif "skipped" in r:
                    cells.append("skip — full attention (DESIGN.md §5)")
                    n_skip += 1
                elif "error" in r:
                    cells.append("ERROR")
                else:
                    n_ok += 1
                    mem = r.get("memory_analysis", {})
                    peak = mem.get("peak_memory_in_bytes") or \
                        (mem.get("argument_size_in_bytes", 0) +
                         mem.get("temp_size_in_bytes", 0))
                    note = " (scan-corrected)" if r.get("unrolled") == "corrected" else ""
                    cells.append(f"OK, peak {fmt_bytes(peak)}, compile "
                                 f"{r.get('compile_s', 0):.0f}s{note}")
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} |", file=out)
    print(f"\nCompiled: **{n_ok}** runs OK ({n_skip//1} documented skips); "
          f"all multi-pod lowers prove the `pod` axis shards.", file=out)
    return out.getvalue()


def roofline_table(recs):
    out = io.StringIO()
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| useful (6ND/HLO) | dominant collectives |", file=out)
    print("|---|---|---|---|---|---|---|---|", file=out)
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = get(recs, a, s, "16x16")
            if not r or "compute_s" not in r:
                continue
            coll = sorted(((v, k) for k, v in r.get("collectives", {}).items()
                           if v), reverse=True)[:2]
            cstr = ", ".join(f"{k}={fmt_bytes(v)}" for v, k in coll) or "-"
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.4f} | {r['bottleneck']} "
                  f"| {r['useful_ratio']:.2f} | {cstr} |", file=out)
    return out.getvalue()


def perf_log(recs):
    out = io.StringIO()

    def terms(r):
        return (f"compute {r['compute_s']*1e3:.2f} ms / memory "
                f"{r['memory_s']*1e3:.2f} ms / collective "
                f"{r['collective_s']*1e3:.2f} ms -> {r['bottleneck']}")

    # pair 1
    print("### Pair 1 — llama4-maverick / qwen3-moe `decode_32k` "
          "(most collective-bound)\n", file=out)
    print("**Iteration 1** — *Hypothesis*: the collective term is dominated "
          "by the FSDP just-in-time expert-weight all-gather (napkin: "
          "~4 GB of expert weights per MoE layer moved to serve 8 local "
          "tokens; tokens themselves are ~1 MB).  *Change*: "
          "`moe.DECODE_BROADCAST` — all-gather the (tiny) token block, "
          "compute on resident weight shards, psum the (T,d) partials over "
          "(model, data) (src/repro/models/moe.py).\n", file=out)
    for arch in ("llama4-maverick-400b-a17b", "qwen3-moe-235b-a22b"):
        b = get(recs, arch, "decode_32k", "16x16")
        o = get(recs, arch, "decode_32k", "16x16", "moebcast")
        if b and o and "compute_s" in b and "compute_s" in o:
            x = b["collective_s"] / max(o["collective_s"], 1e-12)
            print(f"- {arch}: before {terms(b)}; after {terms(o)} — "
                  f"**collective term ÷{x:,.0f}**, bottleneck flips to "
                  f"memory. **CONFIRMED** (predicted >=10x; got more because "
                  f"the baseline all-gathered weights for *every* MoE layer).",
                  file=out)
    print("\nResidual memory-term difference between the runs reflects the "
          "two estimation modes (scan-corrected baseline vs unrolled "
          "optimized); the collective term is robust across both.\n", file=out)

    # pair 2
    print("### Pair 2 — qwen3-14b `prefill_32k` (worst collective absolute, "
          "useful=0.54)\n", file=out)
    b = get(recs, "qwen3-14b", "prefill_32k", "16x16")
    c = get(recs, "qwen3-14b", "prefill_32k", "16x16", "cacheshard")
    h = get(recs, "qwen3-14b", "prefill_32k", "16x16", "headaware")
    if b:
        print(f"Baseline: {terms(b)}; all-reduce "
              f"{fmt_bytes(b['collectives'].get('all-reduce', 0))}/device "
              f"(vs qwen3-8b's 78 GB — 23x more for a 1.75x model).\n", file=out)
    print("**Iteration 1** — *Hypothesis*: AUTO out-shardings replicate the "
          "returned 172 GB KV cache (TB-scale all-gathers).  *Change*: "
          "explicit `out_shardings` (batch->data, seq->model) "
          "(`REPRO_PREFILL_CACHE_SHARDED`).", file=out)
    if b and c:
        print(f"- before {terms(b)}; after {terms(c)} — no improvement. "
              f"**REFUTED**: XLA already kept caches sharded; the "
              f"all-gather delta (13.4->37.6 GB) is noise against the "
              f"1 827 GB all-reduce term.\n", file=out)
    print("**Iteration 2** — *Hypothesis* (from the collective breakdown): "
          "qwen3-14b has 40 q heads on a 16-way model axis; sharding the "
          "fused (40x128) projection leaves 2.5 heads/shard and GSPMD "
          "resolves the (B,S,40,128) reshape with per-layer f32 all-reduces "
          "(~45 GB x 40 layers).  *Change*: replicate attention weights when "
          "head count % axis != 0 and let batch parallelism carry "
          "(`REPRO_ATTN_HEAD_AWARE`, src/repro/models/attention.py).", file=out)
    if b and h:
        x = b["collective_s"] / max(h["collective_s"], 1e-12)
        print(f"- before {terms(b)}; after {terms(h)} — collective term "
              f"÷{x:,.1f} (hypothesis CONFIRMED: the all-reduces came from "
              f"head misalignment), **but** compute x"
              f"{h['compute_s']/b['compute_s']:.1f} and memory x"
              f"{h['memory_s']/b['memory_s']:.1f}: replication un-shards "
              f"attention compute (16x/device) — a bad trade overall. "
              f"**Partially refuted**; keep the diagnosis, change the fix.\n",
              file=out)
    print("**Iteration 3** — *Hypothesis*: pad q heads per kv group to the "
          "next multiple of 16 (40 -> 48, dead heads with zero wo rows: "
          "exactly the same function, verified to 4e-7) so whole heads shard "
          "per device; napkin: +20% q-proj / +20% score FLOPs, collectives "
          "like iteration 2, compute stays sharded "
          "(`REPRO_ATTN_PAD_HEADS`, src/repro/models/attention.py).", file=out)
    pd = get(recs, "qwen3-14b", "prefill_32k", "16x16", "padheads")
    if b and pd:
        x = b["collective_s"] / max(pd["collective_s"], 1e-12)
        print(f"- qwen3-14b: before {terms(b)}; after {terms(pd)} — "
              f"**collective ÷{x:.1f}, memory "
              f"-{(1-pd['memory_s']/b['memory_s'])*100:.0f}%, compute "
              f"+{(pd['compute_s']/b['compute_s']-1)*100:.0f}%** "
              f"(predicted +15-20%). **CONFIRMED** — the dominant term and "
              f"the memory term both drop; the bottleneck is now memory.",
              file=out)
    bl = get(recs, "llama4-maverick-400b-a17b", "prefill_32k", "16x16")
    pl = get(recs, "llama4-maverick-400b-a17b", "prefill_32k", "16x16", "padheads")
    if bl and pl:
        x = bl["collective_s"] / max(pl["collective_s"], 1e-12)
        print(f"- llama4-maverick (same 40-head layout): collective "
              f"÷{x:.1f}, memory -{(1-pl['memory_s']/bl['memory_s'])*100:.0f}% "
              f"— the fix generalizes across the family.\n", file=out)
    print("Stopping rule: after iteration 3 the dominant term is the "
          "fusion-pessimistic memory bound (DESIGN.md section 9.5 caveat 2); "
          "further collective work is <5% of the roofline sum.\n", file=out)

    # extension: multi-pod expert FSDP
    print("### Extension — llama4-maverick `train_4k` on 2x16x16: experts "
          "over the pod axis\n", file=out)
    be = get(recs, "llama4-maverick-400b-a17b", "train_4k", "2x16x16")
    oe = get(recs, "llama4-maverick-400b-a17b", "train_4k", "2x16x16", "expod")
    if be and oe:
        pb = be.get("memory_analysis", {}).get("peak_memory_in_bytes", 0)
        po = oe.get("memory_analysis", {}).get("peak_memory_in_bytes", 0)
        print("*Hypothesis*: the 22.25 GB/device peak (exceeds v5e's 16 GB "
              "HBM -> the 400B config does NOT deploy) is dominated by f32 "
              "AdamW moments of expert weights sharded over only "
              "(model x data) = 256 ranks; sharding the expert dim over "
              "(pod x model) = 32 ranks halves expert state per device at "
              "the cost of one activation all-gather over the pod link per "
              "MoE layer.  *Change*: `REPRO_MOE_EXPERTS_OVER_POD` "
              "(src/repro/models/moe.py, correctness-tested vs the local "
              "oracle).", file=out)
        print(f"- peak memory/device: **{pb/1e9:.2f} GB -> {po/1e9:.2f} GB** "
              f"— now fits v5e HBM. **CONFIRMED** (the 400B train config "
              f"becomes deployable on the 2-pod mesh).\n", file=out)

    # pair 3
    print("### Pair 3 — split pipeline over the pod axis (most "
          "representative of the paper)\n", file=out)
    fn = os.path.join(D, "pipeline_xlstm-125m_wire_modes.json")
    if os.path.exists(fn):
        rec = json.load(open(fn))
        res = rec["results"]
        raw = res["raw"]["collective_permute_bytes"]
        print("The paper's claim on TPU: what crosses the inter-pod link "
              f"(xlstm-125m, butterfly after layer {rec['layer']}, "
              f"d_r={rec['d_r']}, seq {rec['seq']}, "
              f"{rec['num_microbatches']}x{rec['microbatch']} microbatches; "
              "collective-permute payloads in the compiled 2x16x16 HLO):\n",
              file=out)
        print("| wire mode | inter-pod bytes | vs raw | inter-pod time @50GB/s |",
              file=out)
        print("|---|---|---|---|", file=out)
        for mode, label in (("raw", "raw activation (prior art [6]-[12])"),
                            ("reduced", "butterfly reduction only"),
                            ("int8", "reduction + int8 wire (the paper)")):
            r = res[mode]
            print(f"| {label} | {fmt_bytes(r['collective_permute_bytes'])} "
                  f"| {raw / r['collective_permute_bytes']:.1f}x "
                  f"| {r['inter_pod_s']*1e3:.3f} ms |", file=out)
        print("", file=out)
    return out.getvalue()


def wire_table(recs):
    out = io.StringIO()
    from repro.configs import get_config
    from repro.serving.pipeline import wire_stats
    print("| arch | boundary tensor | wire bytes/microbatch | compression |",
          file=out)
    print("|---|---|---|---|", file=out)
    for arch in ("qwen3-8b", "gemma3-12b", "zamba2-7b", "xlstm-125m"):
        base = get_config(arch)
        cfg = base.with_butterfly(layer=max(1, base.num_layers // 8),
                                  d_r=max(16, base.d_model // 64))
        s = wire_stats(cfg, microbatch=8, seq=4096)
        print(f"| {arch} | (8, 4096, {base.d_model}) bf16 "
              f"| {fmt_bytes(s['wire_bytes'])} | {s['compression']:.1f}x |",
              file=out)
    return out.getvalue()


def main():
    recs = load(D)
    src = open(MD).read()
    sections = {
        "<!-- DRYRUN_TABLE -->": dryrun_table(recs),
        "<!-- ROOFLINE_TABLE -->": roofline_table(recs),
        "<!-- PERF_LOG -->": perf_log(recs),
        "<!-- WIRE_TABLE -->": wire_table(recs),
    }
    for marker, content in sections.items():
        # replace everything between the marker and the next "## " heading
        pat = re.escape(marker) + r".*?(?=\n## |\Z)"
        repl = marker + "\n" + content.rstrip() + "\n"
        src = re.sub(pat, repl.replace("\\", r"\\"), src, flags=re.S)
    open(MD, "w").write(src)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
