from repro.data.pipeline import (
    ImageTaskConfig,
    LMStreamConfig,
    MarkovLMStream,
    SyntheticImages,
    image_batches,
    lm_batches,
    shard_batch,
)

__all__ = [
    "ImageTaskConfig", "LMStreamConfig", "MarkovLMStream", "SyntheticImages",
    "image_batches", "lm_batches", "shard_batch",
]
