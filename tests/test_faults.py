"""Fault injection, recovery, and mid-request migration (DESIGN.md sec. 15).

Covers the chaos invariants the fault layer must hold:

* a seeded random fault schedule always terminates with every request
  accounted for (done | failed), breakdowns still sum to latency, and the
  chaotic Chrome trace still validates;
* mid-decode migration (device eviction, and a link handover) resumes the
  streamed decode bitwise-identically to the uninterrupted run;
* a recorded chaotic run replays byte-for-byte, fault schedule included
  (arrival-trace-v2), and v1 traces stay readable;
* a run with no faults configured is telemetry-byte-identical to one with
  an *empty* schedule (the fault layer's observer effect is zero);
* cloud outage degrades to edge-only fallback (or fails closed when
  fallback is disabled), and arrivals reroute around evicted devices.
"""
import dataclasses
import json

import pytest

from repro.configs import get_config
from repro.core.profiler import GTX_1080TI, JETSON_TX2
from repro.runtime.clock import EventLoop
from repro.runtime.faults import (DecodeCheckpoint, FaultEvent, FaultSchedule,
                                  RecoveryPolicy)
from repro.runtime.simulator import (CellSpec, SimConfig, Simulation,
                                     trace_arrivals, trace_faults)
from repro.runtime.tracing import validate_chrome_trace


def small_cfg(layers=4):
    cfg = get_config("qwen3-8b").reduced()
    return dataclasses.replace(cfg, num_layers=layers)


def numerics_cfg(**kw):
    """Tiny real-numerics streamed config: 1 request, 2 devices."""
    base = dict(cfg=small_cfg(2), mode="split", wire_mode="int8",
                transport="streamed", network="3g", num_devices=2,
                num_requests=1, arrival_rate=20.0, prompt_len=8,
                max_new_tokens=5, d_r=16, initial_split=1,
                edge=JETSON_TX2, cloud=GTX_1080TI, max_concurrent=4,
                seed=0, numerics=True)
    base.update(kw)
    return SimConfig(**base)


MIXED = (CellSpec(name="3g0", network="3g", num_devices=2, device="jetson"),
         CellSpec(name="wifi1", network="wifi", num_devices=2,
                  device="phone"))


def topo_cfg(**kw):
    """Timing-only 2-cell topology with adaptive controllers."""
    base = dict(cfg=small_cfg(4), mode="split", wire_mode="int8",
                transport="auto", topology=MIXED, num_requests=16,
                arrival_rate=20.0, prompt_len=32, max_new_tokens=4,
                d_r=16, initial_split=1, edge=JETSON_TX2, cloud=GTX_1080TI,
                adapt=True, max_concurrent=8, seed=0, numerics=False)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------- schedule


def test_fault_schedule_parse_and_roundtrip():
    sched = FaultSchedule.parse(
        "leave@0.05:2, join@0.2:3g0, handover@0.1:3g0>wifi, "
        "blackout@0.15:wifi1+0.05, outage@0.3+0.2")
    kinds = [e.kind for e in sched]
    assert kinds == ["device_leave", "handover", "blackout", "device_join",
                     "cloud_outage"]          # sorted by (t, kind)
    assert sched.events[1].network == "wifi"
    assert sched.events[2].duration == 0.05
    # JSON roundtrip is exact (the arrival-trace-v2 header path)
    again = FaultSchedule.from_obj(json.loads(json.dumps(sched.to_obj())))
    assert again == sched

    with pytest.raises(ValueError):
        FaultSchedule.parse("handover@0.1:3g0")       # missing >network
    with pytest.raises(ValueError):
        FaultSchedule.parse("blackout@0.1:3g0")       # missing +duration
    with pytest.raises(ValueError):
        FaultSchedule.parse("explode@0.1")            # unknown kind
    with pytest.raises(ValueError):
        FaultEvent(t=0.0, kind="nope")


def test_random_schedule_seeded():
    a = FaultSchedule.random(3, cells=("3g0", "wifi1"), num_devices=4)
    b = FaultSchedule.random(3, cells=("3g0", "wifi1"), num_devices=4)
    c = FaultSchedule.random(4, cells=("3g0", "wifi1"), num_devices=4)
    assert a == b
    assert a != c
    assert len(a) == 6
    assert all(e.kind in ("device_leave", "device_join", "handover",
                          "blackout", "cloud_outage") for e in a)


# ------------------------------------------------------------------- clock


def test_event_loop_cancel_handles():
    loop = EventLoop()
    fired = []
    cancel = loop.schedule(1.0, lambda: fired.append("a"))
    loop.schedule(2.0, lambda: fired.append("b"))
    cancel()
    cancel()                                   # idempotent
    loop.run()
    assert fired == ["b"]
    assert loop.now == 2.0


def test_event_loop_cancel_owner():
    loop = EventLoop()
    fired = []
    owner1, owner2 = object(), object()
    loop.schedule(1.0, lambda: fired.append("a"), owner=owner1)
    loop.schedule(2.0, lambda: fired.append("b"), owner=owner1)
    loop.schedule(3.0, lambda: fired.append("c"), owner=owner2)
    assert loop.cancel_owner(owner1) == 2
    assert loop.cancel_owner(owner1) == 0
    loop.run()
    assert fired == ["c"]


# --------------------------------------------------------------- migration


def _baseline_stream():
    sim = Simulation(numerics_cfg())
    tel = sim.run()
    return list(sim.requests[0].engine_req.generated), tel.traces[0]


def test_device_eviction_migrates_decode_bitwise():
    """Evict the home device inside an edge decode step: the in-flight
    streamed decode checkpoints (DecodeCheckpoint) and resumes on the
    other device with a bitwise-identical token stream."""
    toks0, trace0 = _baseline_stream()
    # immediately after the first token lands the request is inside its
    # edge decode step -> the checkpoint/restore path, not just re-homing
    t_leave = trace0.t_first_token + 1e-6
    sim = Simulation(numerics_cfg(faults=f"leave@{t_leave}:0"))
    tel = sim.run()
    assert list(sim.requests[0].engine_req.generated) == toks0
    t = tel.traces[0]
    assert t.outcome == "done"
    assert t.migrations >= 1
    assert tel.counters["fault_decode_migrations"] >= 1
    assert sim.requests[0].home == 1          # resumed on the other device
    assert t.t_done > trace0.t_done           # migration delay was paid


def test_handover_mid_decode_bitwise():
    """A 3g->wifi handover mid-stream re-links the wire under the request;
    the token stream is unaffected (numerics never cross the link model)."""
    toks0, trace0 = _baseline_stream()
    t_mid = (trace0.t_first_token + trace0.t_done) / 2
    sim = Simulation(numerics_cfg(faults=f"handover@{t_mid}:cell0>wifi"))
    tel = sim.run()
    assert list(sim.requests[0].engine_req.generated) == toks0
    assert tel.traces[0].outcome == "done"
    assert tel.counters["fault_handovers"] == 1
    assert sim.cells[0].wire.name == "wifi"


def test_double_eviction_remigrates():
    """Evicting the migration target as well re-migrates from the same
    checkpoint; with a third device alive the stream still completes
    bitwise-identically."""
    toks0, trace0 = _baseline_stream()
    t1 = trace0.t_first_token + 1e-6
    sim = Simulation(numerics_cfg(
        num_devices=3, faults=f"leave@{t1}:0,leave@{t1 + 1e-6}:1"))
    tel = sim.run()
    assert list(sim.requests[0].engine_req.generated) == toks0
    assert tel.traces[0].outcome == "done"
    assert sim.requests[0].home == 2


def test_eviction_with_no_target_fails_request():
    toks0, trace0 = _baseline_stream()
    t1 = trace0.t_first_token + 1e-6
    sim = Simulation(numerics_cfg(
        faults=f"leave@{t1}:0,leave@{t1 + 1e-6}:1"))
    tel = sim.run()
    t = tel.traces[0]
    assert t.outcome == "failed"
    assert t.failure == "device_lost"
    assert abs(sum(t.breakdown().values()) - t.latency_s) < 1e-12


def test_checkpoint_capture_restore_fields():
    class _Req:
        pass
    req = _Req()
    req.trace = type("T", (), {"uid": 7, "split": 1, "transport": "streamed",
                               "prompt_len": 8})()
    req.edge_pos, req.cloud_pos = 10, 9
    req.produced, req.sent_down, req.cloud_served_upto = 3, 3, 9
    req.last_token, req.last_sent = 42, (42, 3)
    req.engine_req = None
    req.edge_cache, req.cloud_cache, req.stream_row = "E", "C", "R"
    ck = DecodeCheckpoint.capture(req)
    req.edge_pos = req.cloud_pos = 0
    req.edge_cache = req.cloud_cache = req.stream_row = None
    ck.restore(req)
    assert (req.edge_pos, req.cloud_pos) == (10, 9)
    assert req.edge_cache == "E" and req.cloud_cache == "C"
    other = _Req()
    other.trace = type("T", (), {"uid": 8})()
    with pytest.raises(AssertionError):
        ck.restore(other)


# -------------------------------------------------------- chaos invariants


def test_chaos_sweep_invariants():
    """Seeded random schedules over the 2-cell topology: every request
    terminates with a valid outcome, breakdowns sum to latency, and the
    chaotic Chrome trace still validates."""
    for seed in range(4):
        sched = FaultSchedule.random(seed, cells=("3g0", "wifi1"),
                                     num_devices=4)
        sim = Simulation(topo_cfg(faults=sched, seed=seed, trace=True))
        tel = sim.run()
        assert all(r.finished for r in sim.requests), f"seed {seed} hung"
        assert len(tel.traces) == 16
        for t in tel.traces:
            assert t.outcome in ("done", "failed")
            assert abs(sum(t.breakdown().values()) - t.latency_s) < 1e-12
        s = tel.summary()
        assert s["n_done"] + s["n_failed"] == 16
        assert 0.0 <= s["availability_pct"] <= 100.0
        validate_chrome_trace(json.loads(sim.tracer.to_json()))


def test_explicit_chaos_migrations_and_retries():
    sim = Simulation(topo_cfg(
        faults="leave@0.02:1,handover@0.05:3g0>wifi,"
               "blackout@0.08:wifi1+0.03,outage@0.12+0.1"))
    tel = sim.run()
    assert all(r.finished for r in sim.requests)
    c = tel.counters
    assert c["fault_device_leaves"] == 1
    assert c["fault_handovers"] == 1
    assert c["fault_blackouts"] == 1
    assert c["fault_cloud_outages"] == 1
    assert c["fault_retries"] >= 1            # outage dropped in-flight work
    # the handover poked the cell's controller out-of-band
    assert any(d.reason == "handover" for d in tel.decisions)


def test_no_faults_is_byte_identical_to_empty_schedule():
    """The fault layer's observer effect is zero: faults=None and an empty
    FaultSchedule (injector active, nothing scheduled) must produce
    byte-identical telemetry."""
    t_none = Simulation(topo_cfg()).run().to_json()
    t_empty = Simulation(topo_cfg(faults=FaultSchedule(()))).run().to_json()
    assert t_none == t_empty


def test_watchdog_fails_stuck_requests():
    """A permanent total blackout of a cell's wire with retries disabled
    would stall forever; the watchdog surfaces the stuck requests as
    ``failed`` and Simulation.run terminates."""
    pol = RecoveryPolicy(max_retries=0, edge_fallback=False,
                         request_timeout_s=1.0, phase_timeout_s=5.0)
    sim = Simulation(topo_cfg(faults="blackout@0.0:3g0+1e9",
                              recovery=pol))
    tel = sim.run()
    assert all(r.finished for r in sim.requests)
    failed = [t for t in tel.traces if t.outcome == "failed"]
    assert failed, "watchdog never fired"
    assert all(t.failure in ("request_timeout", "lost",
                             "payload_retries_exhausted",
                             "row_retries_exhausted")
               for t in failed)


# ------------------------------------------------------- outage + fallback


def test_permanent_outage_edge_fallback():
    tel = Simulation(topo_cfg(faults="outage@0.0+1e9")).run()
    s = tel.summary()
    assert s["n_done"] == 16 and s["n_failed"] == 0
    assert s["n_fallback"] == 16
    assert all(t.fallback == "edge" for t in tel.traces)
    assert s["availability_pct"] == 100.0


def test_permanent_outage_no_fallback_fails_closed():
    tel = Simulation(topo_cfg(
        faults="outage@0.0+1e9",
        recovery=RecoveryPolicy(edge_fallback=False))).run()
    s = tel.summary()
    assert s["n_done"] == 0 and s["n_failed"] == 16
    assert s["availability_pct"] == 0.0
    for t in tel.traces:
        assert abs(sum(t.breakdown().values()) - t.latency_s) < 1e-12


# ------------------------------------------------------ churn (join/leave)


def test_arrivals_reroute_around_evicted_device():
    """Evict a device before traffic starts: its arrivals land on the
    surviving device in the cell and every request completes."""
    sim = Simulation(topo_cfg(faults="leave@0.0:0"))
    tel = sim.run()
    assert tel.summary()["availability_pct"] == 100.0
    assert tel.counters["fault_rerouted_arrivals"] >= 1
    assert all(r.home != 0 for r in sim.requests)


def test_device_join_grows_fleet():
    sim = Simulation(topo_cfg(faults="join@0.01:3g0"))
    tel = sim.run()
    assert len(sim.devices) == 5
    joined = sim.devices[-1]
    assert joined.cell == "3g0" and not joined.evicted
    assert tel.summary()["availability_pct"] == 100.0


# -------------------------------------------------- trace record / replay


def test_chaos_record_replay_byte_identical(tmp_path):
    """A recorded chaotic run replays byte-for-byte — telemetry JSON and
    Chrome trace — with the fault schedule restored from the v2 header."""
    path = str(tmp_path / "chaos.jsonl")
    cfg = topo_cfg(faults="leave@0.02:1,outage@0.1+0.05", trace=True)
    sim_a = Simulation(cfg)
    sim_a.record_trace(path)
    tel_a = sim_a.run()

    faults = trace_faults(path)
    assert faults is not None and len(faults) == 2
    sim_b = Simulation(dataclasses.replace(
        cfg, arrivals=trace_arrivals(path), faults=faults))
    tel_b = sim_b.run()
    assert tel_a.to_json() == tel_b.to_json()
    assert sim_a.tracer.to_json() == sim_b.tracer.to_json()


def test_empty_schedule_recorded_in_header(tmp_path):
    """Recording a run with an *empty* schedule still writes the faults key
    (so the replay re-enables the watchdog/fault layer)."""
    path = str(tmp_path / "calm.jsonl")
    sim = Simulation(topo_cfg(faults=FaultSchedule(())))
    sim.record_trace(path)
    faults = trace_faults(path)
    assert faults is not None and len(faults) == 0


def test_v1_trace_still_readable(tmp_path):
    """A pre-fault (arrival-trace-v1) file replays fine: no faults key
    means no injector."""
    path = str(tmp_path / "v1.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"format": "arrival-trace-v1", "n": 2}) + "\n")
        f.write(json.dumps({"cell": 0, "device": 0, "t": 0.01,
                            "tokens": None}, sort_keys=True) + "\n")
        f.write(json.dumps({"cell": 0, "device": 1, "t": 0.02,
                            "tokens": None}, sort_keys=True) + "\n")
    arrivals = trace_arrivals(path)
    assert len(arrivals) == 2
    assert trace_faults(path) is None
    sim = Simulation(SimConfig(
        cfg=small_cfg(4), mode="split", wire_mode="int8", network="3g",
        num_devices=2, num_requests=2, prompt_len=16, max_new_tokens=1,
        d_r=16, edge=JETSON_TX2, cloud=GTX_1080TI, numerics=False,
        arrivals=arrivals))
    tel = sim.run()
    assert sim.injector is None
    assert len(tel.traces) == 2


# ----------------------------------------------------------- fault traces


def test_fault_events_in_chrome_trace():
    sim = Simulation(topo_cfg(faults="outage@0.05+0.05", trace=True))
    sim.run()
    doc = json.loads(sim.tracer.to_json())
    faults = [e for e in doc["traceEvents"] if e.get("cat") == "fault"]
    assert len(faults) == 1
    assert faults[0]["args"]["kind"] == "cloud_outage"
    validate_chrome_trace(doc)
    # the validator rejects fault events without args.kind
    bad = json.loads(sim.tracer.to_json())
    for e in bad["traceEvents"]:
        if e.get("cat") == "fault":
            del e["args"]
    with pytest.raises(ValueError, match="fault event missing args.kind"):
        validate_chrome_trace(bad)
