"""zamba2-7b [hybrid] — Mamba2 backbone with a *shared-parameter* attention
block applied periodically (every 6th position here), ssm_state=64.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        arch_type="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,                   # shared-attention block ffn
        vocab_size=32000,
        act="gelu",
        rope_theta=1e4,
        tie_embeddings=True,
        hybrid_attn_every=6,          # layer i is shared-attn when i % 6 == 5
        # d_inner = 2*d_model = 7168 = 64 heads x 112; 64 heads shard evenly
        # over the 16-way model axis (DESIGN.md section 6)
        ssm=SSMConfig(state_dim=64, num_heads=64, head_dim=112,
                      conv_width=4, chunk_size=128, expand=2),
        source="arXiv:2411.15242 (Zamba2-7B: 81 blocks, shared attn, ssm_state=64)",
    )
