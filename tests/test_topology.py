"""Multi-cell topologies: heterogeneous fleets, per-cell adaptive control,
trace replay, windowed goodput feedback, and pluggable controller
objectives.

The load-bearing invariants:
  * a 1-cell Topology reproduces the classic single-uplink SimConfig
    telemetry exactly (same seed -> identical latency/energy/decision log)
  * record -> replay is byte-for-byte deterministic
  * per-cell contention is isolated (saturating cell A's 3g uplink leaves
    cell B's wifi wait at 0) while all cells share one cloud
  * per-cell controllers diverge when their cells' conditions differ
  * the Wire's goodput feedback is windowed: the controller re-adapts after
    a load transient clears (a lifetime average never recovers)
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.planner import (SELECTION_OBJECTIVES, register_objective,
                                select_split_online)
from repro.core.profiler import (DEVICE_CLASSES, JETSON_TX2, PHONE_NPU,
                                 get_device_class)
from repro.core.wireless import NETWORKS
from repro.runtime.clock import EventLoop
from repro.runtime.controller import AdaptiveSplitController
from repro.runtime.simulator import (Arrival, CellSpec, SimConfig, Simulation,
                                     parse_topology, poisson_arrivals,
                                     record_arrivals, trace_arrivals)
from repro.runtime.split_exec import CostModel
from repro.runtime.telemetry import Telemetry
from repro.runtime.wire import Wire


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


MIXED = (CellSpec(name="3g0", network="3g", num_devices=4, device="jetson"),
         CellSpec(name="wifi1", network="wifi", num_devices=4,
                  device="phone"))


def trace_key(tel):
    return [(t.uid, t.device, t.cell, t.split, t.transport,
             t.t_arrival, t.t_edge_start, t.t_edge_done, t.t_uplink_start,
             t.t_uplink_done, t.t_cloud_start, t.t_first_token,
             t.t_cloud_done, t.t_done, t.wire_bytes, t.downlink_bytes,
             t.mobile_energy_mj) for t in tel.traces]


def decision_key(tel):
    return [(d.t, d.cell, d.cloud_load, d.link_bytes_per_s, d.old_split,
             d.new_split, d.transport) for d in tel.decisions]


# ---------------------------------------------------------------------------
# topology spec grammar + device classes
# ---------------------------------------------------------------------------


def test_parse_topology_grammar():
    cells = parse_topology("3g:4xphone,wifi:2xjetson")
    assert [c.name for c in cells] == ["3g0", "wifi1"]
    assert cells[0].num_devices == 4 and cells[0].device == "phone"
    assert cells[1].num_devices == 2 and cells[1].device == "jetson"
    one = parse_topology("4g/shared:8xphone@30.5")[0]
    assert one.duplex == "shared" and one.arrival_rate == 30.5
    with pytest.raises(ValueError):
        parse_topology("3g:phone")               # missing <N>x
    with pytest.raises(KeyError):
        parse_topology("3g:4xmainframe")         # unknown device class


def test_device_classes_resolve():
    assert get_device_class("jetson") is JETSON_TX2
    assert get_device_class("phone") is PHONE_NPU
    assert get_device_class(PHONE_NPU) is PHONE_NPU
    assert PHONE_NPU.flops < JETSON_TX2.flops    # the weak end of the fleet
    assert set(DEVICE_CLASSES) >= {"phone", "jetson"}
    with pytest.raises(KeyError):
        get_device_class("mainframe")


# ---------------------------------------------------------------------------
# single-cell equivalence: the classic config IS the 1-cell topology
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("adapt", [False, True])
def test_single_cell_topology_equivalence(adapt):
    """A 1-cell Topology must reproduce the classic
    SimConfig(network=..., num_devices=...) run exactly: same seed ->
    identical latency/energy traces and decision log."""
    kw = dict(num_requests=24, max_new_tokens=4, adapt=adapt,
              control_interval_s=0.02)
    legacy = Simulation(timing_cfg(**kw)).run()
    one_cell = (CellSpec(name="cell0", network="3g", num_devices=4,
                         device="jetson"),)
    topo = Simulation(timing_cfg(topology=one_cell, **kw)).run()
    assert trace_key(legacy) == trace_key(topo)
    assert decision_key(legacy) == decision_key(topo)
    assert legacy.summary() == topo.summary()
    if adapt:
        assert legacy.decisions, "controller never ran"


def test_single_cell_topology_equivalence_numerics():
    """Numerics mode too: identical greedy tokens through both paths."""
    kw = dict(cfg=small_cfg(layers=2), num_devices=2, num_requests=4,
              prompt_len=16, max_new_tokens=2, max_concurrent=2,
              numerics=True)
    legacy_sim = Simulation(timing_cfg(**kw))
    legacy = legacy_sim.run()
    topo_sim = Simulation(timing_cfg(
        topology=(CellSpec(name="cell0", network="3g", num_devices=2,
                           device="jetson"),), **kw))
    topo = topo_sim.run()
    assert trace_key(legacy) == trace_key(topo)
    assert [list(r.engine_req.generated) for r in legacy_sim.requests] == \
        [list(r.engine_req.generated) for r in topo_sim.requests]


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------


def multi_cell_cfg(**kw):
    defaults = dict(topology=MIXED, num_requests=32, prompt_len=64,
                    max_new_tokens=8, adapt=True, transport="auto",
                    control_interval_s=0.02,
                    background_load=lambda t: 0.95)
    defaults.update(kw)
    return timing_cfg(**defaults)


def test_record_replay_is_byte_identical(tmp_path):
    path = tmp_path / "trace.jsonl"
    sim = Simulation(multi_cell_cfg())
    sim.record_trace(str(path))
    tel = sim.run()

    replay_sim = Simulation(multi_cell_cfg(arrivals=trace_arrivals(str(path))))
    tel2 = replay_sim.run()
    # identical telemetry: every timestamp, per-cell byte count, decision
    assert trace_key(tel) == trace_key(tel2)
    assert decision_key(tel) == decision_key(tel2)
    assert tel.cell_summary() == tel2.cell_summary()
    assert tel.to_json() == tel2.to_json()
    for t in tel2.traces:
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)
    # record -> replay -> record round-trips the file bytes exactly
    path2 = tmp_path / "trace2.jsonl"
    replay_sim.record_trace(str(path2))
    assert path.read_bytes() == path2.read_bytes()


def test_trace_tokens_round_trip(tmp_path):
    """Numerics traces carry the prompt ids exactly."""
    arr = poisson_arrivals(num_devices=2, num_requests=6, arrival_rate=20.0,
                           prompt_len=8, vocab_size=512, seed=3,
                           device_offset=2, cell=1)
    path = tmp_path / "t.jsonl"
    record_arrivals(arr, str(path))
    back = trace_arrivals(str(path))
    assert len(back) == len(arr)
    for a, b in zip(arr, back):
        assert (a.device, a.cell, a.t) == (b.device, b.cell, b.t)
        assert b.tokens.dtype == np.int32
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_replay_rejects_mismatched_topology(tmp_path):
    path = tmp_path / "trace.jsonl"
    sim = Simulation(multi_cell_cfg())
    sim.record_trace(str(path))
    arrivals = trace_arrivals(str(path))
    with pytest.raises(AssertionError,
                       match="outside the fleet|does not match"):
        Simulation(timing_cfg(arrivals=arrivals))     # 1-cell, 4 devices
    with pytest.raises(AssertionError, match="does not match"):
        # right device count, wrong cell layout (8 devices in one cell)
        Simulation(timing_cfg(num_devices=8, arrivals=arrivals))
    with pytest.raises(AssertionError, match="not an arrival trace"):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"nope": 1}\n')
        trace_arrivals(str(bad))


# ---------------------------------------------------------------------------
# per-cell contention isolation + shared-cloud coupling
# ---------------------------------------------------------------------------


def test_contention_isolated_per_cell():
    """Saturating cell A's 3g uplink must not add a microsecond of wait to
    cell B's wifi — each cell owns its radio."""
    topo = (CellSpec(name="3gA", network="3g", num_devices=4,
                     device="jetson", arrival_rate=500.0, num_requests=24),
            CellSpec(name="wifiB", network="wifi", num_devices=2,
                     device="jetson", arrival_rate=5.0, num_requests=8))
    sim = Simulation(timing_cfg(topology=topo, num_requests=32))
    tel = sim.run()
    a_wire, b_wire = sim.cells[0].wire, sim.cells[1].wire
    assert a_wire is not b_wire
    assert a_wire.stats.wait_s > 0, "3g cell never contended"
    assert b_wire.stats.wait_s == 0.0
    for t in tel.traces:
        if t.cell == "wifiB":
            assert t.uplink_wait_s == 0.0
    assert {t.cell for t in tel.traces} == {"3gA", "wifiB"}
    assert sum(1 for t in tel.traces if t.cell == "3gA") == 24


def test_shared_wire_group_couples_cells():
    """Cells in one wire group share a single physical Wire: the same fleet
    forced through one congested 3g uplink contends cross-cell."""
    shared = (CellSpec(name="3gA", network="3g", num_devices=4,
                       device="jetson", arrival_rate=500.0, num_requests=24,
                       wire="ur"),
              CellSpec(name="B", network="3g", num_devices=2,
                       device="phone", arrival_rate=5.0, num_requests=8,
                       wire="ur"))
    sim = Simulation(timing_cfg(topology=shared, num_requests=32))
    tel = sim.run()
    assert sim.cells[0].wire is sim.cells[1].wire
    b_waits = [t.uplink_wait_s for t in tel.traces if t.cell == "B"]
    assert max(b_waits) > 0, "shared wire never queued cell B behind cell A"


def test_cross_cell_cloud_congestion_is_shared():
    """All cells contend for ONE CloudServer: a single cell's burst raises
    the load every cell's controller observes."""
    topo = (CellSpec(name="busy", network="wifi", num_devices=8,
                     device="jetson", arrival_rate=2000.0, num_requests=40),
            CellSpec(name="idle", network="wifi", num_devices=1,
                     device="jetson", arrival_rate=1.0, num_requests=2))
    sim = Simulation(timing_cfg(topology=topo, num_requests=42,
                                max_new_tokens=8, max_concurrent=4,
                                adapt=True, control_interval_s=0.005))
    tel = sim.run()
    idle_loads = [d.cloud_load for d in tel.decisions if d.cell == "idle"]
    assert max(idle_loads) > 0, \
        "idle cell's controller never saw the busy cell's occupancy"
    assert sim.server.peak_active <= 4


# ---------------------------------------------------------------------------
# per-cell adaptive control: heterogeneous cells diverge
# ---------------------------------------------------------------------------


def final_decisions(sim, tel):
    out = {}
    for cell in sim.cells:
        ds = [d for d in tel.decisions if d.cell == cell.name]
        assert ds, f"cell {cell.name} never decided"
        out[cell.name] = (ds[-1].new_split, ds[-1].transport)
    return out


def test_per_cell_controllers_diverge():
    """The checked-in topology benchmark's scenario: jetson-class gateways
    on a 3g backhaul vs phones on home wifi, one congested cloud.  The 3g
    cell settles on a deeper split than the wifi cell (its fast edge
    carries more of the congested cloud's work), and requests admitted
    after settling actually carry the per-cell splits."""
    sim = Simulation(multi_cell_cfg())
    tel = sim.run()
    finals = final_decisions(sim, tel)
    split_3g, _ = finals["3g0"]
    split_wifi, _ = finals["wifi1"]
    assert split_3g > split_wifi
    late = max(t.t_arrival for t in tel.traces) * 0.5
    late_3g = {t.split for t in tel.traces
               if t.cell == "3g0" and t.t_arrival > late}
    late_wifi = {t.split for t in tel.traces
                 if t.cell == "wifi1" and t.t_arrival > late}
    assert late_3g == {split_3g} and late_wifi == {split_wifi}


def test_fairness_report():
    sim = Simulation(multi_cell_cfg())
    tel = sim.run()
    cells = tel.cell_summary()
    assert set(cells) == {"3g0", "wifi1"}
    assert sum(c["n_requests"] for c in cells.values()) == len(tel.traces)
    fair = tel.fairness()
    assert fair["n_cells"] == 2
    assert fair["max_min_latency_ratio"] >= 1.0
    assert fair["p95_spread_ms"] >= 0.0
    assert 0.5 <= fair["jain_index"] <= 1.0      # n=2: jain in [1/2, 1]
    # a single-cell run is trivially fair
    single = Simulation(timing_cfg()).run().fairness()
    assert single["n_cells"] == 1
    assert single["jain_index"] == pytest.approx(1.0)
    assert single["max_min_latency_ratio"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# windowed goodput feedback (the observed_bytes_per_s(now) fix)
# ---------------------------------------------------------------------------


def test_observed_goodput_window_forgets_cleared_transient():
    net = NETWORKS["3g"]
    w = Wire(net, window_s=0.5)
    nominal = w.nominal_bytes_per_s()
    nbytes, n = 11_000, 20
    for _ in range(n):                       # burst at t=0: deep FIFO queue
        w.transfer(nbytes, 0.0)
    congested = w.observed_bytes_per_s(w.free_at)
    assert congested < nominal / 5           # waits crush the goodput
    # lifetime totals keep the whole history for telemetry ...
    assert w.stats.n_transfers == n
    assert w.stats.bytes_sent == n * nbytes
    assert w.stats.wait_s > 0
    # ... but once the transient drains past the window, the signal recovers
    assert w.observed_bytes_per_s(w.free_at + w.window_s + 1e-9) == nominal
    # and an uncontended transfer long after reads nominal, not the average
    quiet_t = w.free_at + 10.0
    w.transfer(nbytes, quiet_t)
    assert w.observed_bytes_per_s(quiet_t + 1.0) == pytest.approx(nominal)
    assert w.stats.n_transfers == n + 1      # totals still accumulate


def test_controller_readapts_after_transient_clears():
    """Regression for the lifetime-average feedback bug: a transient that
    saturates the uplink flips the pick (cache handoff's KV shipment stops
    paying off), and once the transient drains past the window the
    controller must return to its pre-transient decision."""
    cfg = small_cfg()
    cloud = PHONE_NPU.scaled(1000, "big_cloud")
    wire = Wire(NETWORKS["wifi"], window_s=0.5)
    cost = CostModel(cfg, PHONE_NPU, cloud)
    tel = Telemetry()
    state = {"split": 1, "transport": "cache_handoff"}
    ctl = AdaptiveSplitController(
        loop=EventLoop(), uplink=wire, cloud_load=lambda t: 0.0,
        cfg=cfg, d_r=16, seq=8, candidate_splits=[1, 2, 3],
        edge=PHONE_NPU, cloud=cloud, wire_mode="int8", telemetry=tel,
        set_split=lambda s: state.update(split=s),
        get_split=lambda: state["split"],
        handoff_bytes_per_layer=cost.stage0_cache_bytes(8, 1),
        transport_mode="auto", new_tokens=64,
        set_transport=lambda t: state.update(transport=t),
        get_transport=lambda: state["transport"])
    ctl.decide(0.0)
    before = dict(state)
    assert before["transport"] == "cache_handoff"    # fat pipe: ship the KV
    # transient: a burst saturates the uplink, observed goodput collapses
    for _ in range(60):
        wire.transfer(11_800, 0.0)
    ctl.decide(wire.free_at)
    during = dict(state)
    assert during["transport"] == "streamed"         # KV unaffordable now
    assert tel.decisions[-1].link_bytes_per_s < \
        wire.nominal_bytes_per_s() / 5
    # transient clears: past the window the controller re-adapts.  With the
    # old lifetime average the goodput — and the pick — never recovered.
    t_clear = wire.free_at + wire.window_s + 1e-6
    ctl.decide(t_clear)
    assert dict(state) == before
    assert tel.decisions[-1].link_bytes_per_s == \
        pytest.approx(wire.nominal_bytes_per_s())


# ---------------------------------------------------------------------------
# pluggable selection objectives
# ---------------------------------------------------------------------------


def objective_kw(cloud_load=0.95):
    return dict(candidate_splits=[1, 2, 3], edge=JETSON_TX2,
                cloud=JETSON_TX2.scaled(10), cloud_load=cloud_load,
                link_bytes_per_s=NETWORKS["wifi"].uplink_mbps * 1e6 / 8,
                link_energy_mj_per_byte=1e-3)


def test_energy_under_slo_objective():
    cfg = small_cfg()
    lat_best, rows = select_split_online(cfg, 32, 16, objective="latency",
                                         **objective_kw())
    en_best, _ = select_split_online(cfg, 32, 16, objective="energy",
                                     **objective_kw())
    # congested cloud: latency wants depth, energy wants the shallow edge
    assert en_best["split"] < lat_best["split"]
    # a loose SLO admits everything -> the energy winner
    loose, _ = select_split_online(cfg, 32, 16, objective="energy_under_slo",
                                   slo_s=10 * lat_best["latency_s"],
                                   **objective_kw())
    assert loose["split"] == en_best["split"]
    # an SLO only the latency winner meets forces the deep split even
    # though it costs more energy
    tight_slo = min(r["latency_s"] for r in rows) * 1.0001
    tight, _ = select_split_online(cfg, 32, 16, objective="energy_under_slo",
                                   slo_s=tight_slo, **objective_kw())
    assert tight["split"] == lat_best["split"]
    assert tight["energy_mj"] > loose["energy_mj"]
    # impossible SLO: best-effort fallback is the least-infeasible row
    hopeless, _ = select_split_online(cfg, 32, 16,
                                      objective="energy_under_slo",
                                      slo_s=1e-12, **objective_kw())
    assert hopeless["split"] == lat_best["split"]
    # the SLO is mandatory for this objective
    with pytest.raises(AssertionError):
        select_split_online(cfg, 32, 16, objective="energy_under_slo",
                            **objective_kw())


def test_objective_registry_is_pluggable():
    cfg = small_cfg()
    with pytest.raises(KeyError, match="unknown selection objective"):
        select_split_online(cfg, 32, 16, objective="vibes", **objective_kw())
    assert {"latency", "energy", "energy_under_slo"} <= \
        set(SELECTION_OBJECTIVES)
    register_objective("deepest", lambda rows, slo_s=None: max(
        rows, key=lambda r: r["split"]))
    try:
        best, _ = select_split_online(cfg, 32, 16, objective="deepest",
                                      **objective_kw())
        assert best["split"] == 3
    finally:
        del SELECTION_OBJECTIVES["deepest"]


def test_energy_under_slo_closed_loop():
    """End to end: under a congested cloud the SLO-bound controller holds
    the deep (fast) split while the unconstrained energy objective drops to
    the shallow low-energy one."""
    kw = dict(num_requests=24, max_new_tokens=1, adapt=True,
              control_interval_s=0.02, cloud=JETSON_TX2.scaled(10),
              background_load=lambda t: 0.95)
    en = Simulation(timing_cfg(objective="energy", **kw)).run()
    lat = Simulation(timing_cfg(objective="latency", **kw)).run()
    assert en.decisions[-1].new_split < lat.decisions[-1].new_split
    # an SLO between the deep pick's predicted latency and the shallow
    # pick's: the controller must spend energy to make the deadline
    _, rows = select_split_online(
        small_cfg(), 32, 16, candidate_splits=[1, 2, 3], edge=JETSON_TX2,
        cloud=JETSON_TX2.scaled(10), cloud_load=0.95,
        link_bytes_per_s=NETWORKS["3g"].uplink_mbps * 1e6 / 8)
    lats = sorted(r["latency_s"] for r in rows)
    slo_ms = (lats[0] + lats[1]) / 2 * 1e3
    slo = Simulation(timing_cfg(objective="energy_under_slo", slo_ms=slo_ms,
                                **kw)).run()
    assert slo.decisions[-1].new_split > en.decisions[-1].new_split
    assert slo.decisions[-1].new_split == lat.decisions[-1].new_split
