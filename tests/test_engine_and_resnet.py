"""Serving engine behaviour + the paper's ResNet reproduction pieces."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.resnet50 import resnet50
from repro.core import costs
from repro.models import model as M
from repro.models.resnet import (apply_butterfly_conv, edge_cloud_split,
                                 forward_resnet, init_resnet)
from repro.serving.engine import ServingEngine


# ---------------------------------------------------------------- engine


def test_engine_matches_sequential_greedy():
    """Batched ragged decode == one-request-at-a-time greedy decode.

    Batch-4 vs batch-1 matmuls differ in f32 summation order, so a greedy
    argmax near-tie may legitimately flip and the sequences diverge after
    it; the assertion therefore requires identical tokens up to the first
    near-tie (logit gap < 1e-3) and close logits at every compared step."""
    cfg = get_config("qwen3-8b").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    prompts = [np.arange(4, 10), np.arange(30, 37), np.arange(100, 103)]

    def solo(prompt):
        eng = ServingEngine(params, built, max_batch=1, max_len=64)
        r = eng.submit(prompt, max_new_tokens=6, record_logits=True)
        eng.run()
        return r

    expected = [solo(p) for p in prompts]

    eng = ServingEngine(params, built, max_batch=4, max_len=64)
    reqs = [eng.submit(p, max_new_tokens=6, record_logits=True)
            for p in prompts]
    eng.run()
    for r, e in zip(reqs, expected):
        for step, (tb, ts) in enumerate(zip(r.generated, e.generated)):
            lb = np.asarray(r.logits_history[step], np.float32)
            ls = np.asarray(e.logits_history[step], np.float32)
            np.testing.assert_allclose(lb, ls, rtol=5e-3, atol=5e-3)
            if tb != ts:
                gap = abs(float(ls[ts]) - float(ls[tb]))
                assert gap < 1e-3, (step, tb, ts, gap)   # true divergence
                break                                    # tie: rest may differ


def test_engine_slot_reuse():
    cfg = get_config("xlstm-125m").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    eng = ServingEngine(params, built, max_batch=2, max_len=64)
    r1 = eng.submit(np.arange(4), max_new_tokens=3)
    r2 = eng.submit(np.arange(5), max_new_tokens=3)
    eng.run()
    assert r1.done and r2.done
    r3 = eng.submit(np.arange(6), max_new_tokens=3)   # reuses a freed slot
    eng.run()
    assert r3.done and len(r3.generated) == 3


def test_engine_step_single_host_sync(monkeypatch):
    """Sampling runs inside the jitted decode: one device_get per step for
    the whole slot pool, none per slot (logits snapshots are opt-in)."""
    cfg = get_config("qwen3-8b").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    eng = ServingEngine(params, built, max_batch=4, max_len=64)
    for p in (np.arange(4, 10), np.arange(30, 37), np.arange(100, 103)):
        eng.submit(p, max_new_tokens=4)

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.append(1) or real(x))
    eng.step()
    assert len(calls) == 1
    assert all(not r.logits_history for r in eng.active if r is not None)


def test_engine_run_honors_requests_done():
    cfg = get_config("qwen3-8b").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    eng = ServingEngine(params, built, max_batch=2, max_len=64)
    r = eng.submit(np.arange(5), max_new_tokens=16)
    eng.run(requests_done=lambda: len(r.generated) >= 3)
    assert not r.done and len(r.generated) == 3     # early exit, slot kept
    eng.run()                                       # and it can finish later
    assert r.done and len(r.generated) == 16


# ---------------------------------------------------------------- resnet


def test_resnet50_structure_matches_paper():
    cfg = resnet50()
    assert cfg.num_blocks == 16                        # paper Fig. 4
    assert cfg.block_channels()[:3] == [256] * 3       # stage 1
    assert cfg.block_channels()[-1] == 2048
    assert cfg.block_spatial()[0] == 56                # 224/4
    assert cfg.block_spatial()[-1] == 7


def test_resnet_forward_and_split_agree():
    cfg = resnet50().reduced().with_butterfly(1, 4)
    params = init_resnet(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, cfg.image_size,
                                              cfg.image_size, 3))
    logits_ingraph = forward_resnet(params, x, cfg, train=True)
    logits_split, wire = edge_cloud_split(params, x, cfg)
    np.testing.assert_allclose(np.asarray(logits_ingraph),
                               np.asarray(logits_split), rtol=1e-4, atol=1e-4)
    assert wire["codes"].dtype == jnp.int8
    # the only offloaded tensor is (B, H, W, d_r) int8 + scales
    assert wire["codes"].shape[-1] == 4


def test_resnet_split_flops_partition():
    cfg = resnet50()
    total_blocks = sum(costs.resnet_block_flops(cfg, b) for b in range(1, 17))
    e1, c1, w1 = costs.resnet_split_flops(cfg, 1, 1)
    e8, c8, w8 = costs.resnet_split_flops(cfg, 8, 5)
    assert e1 < e8                      # deeper split -> more edge compute
    assert w1 > w8                      # ... and less wire data (Table IV)
    # edge+cloud covers all block flops (plus stem/butterfly/head)
    assert e8 + c8 > total_blocks


def test_wire_bytes_match_table4_column():
    """Table IV offloaded KB: RB1-3 ~3.1KB, RB4-7 ~1.6KB, RB8-13 ~1KB,
    RB14-16 ~0.5KB, with the paper's published minimal D_r."""
    from repro.configs.resnet50 import PAPER_MIN_DR
    cfg = resnet50()
    expect = {1: 3.1, 4: 1.6, 8: 1.0, 14: 0.5}
    for rb, kb in expect.items():
        got = cfg.feature_bytes(rb, bits=8, channels=PAPER_MIN_DR[rb]) / 1e3
        assert got == pytest.approx(kb, rel=0.05), (rb, got, kb)
