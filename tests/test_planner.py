"""Algorithm 1 tests, including exact reproduction of the paper's Table V
selections and headline improvement factors from its own Table IV profile."""
import pytest

from repro.core.planner import (Selection, select_from_table, selection_phase,
                                training_phase, profiling_phase,
                                TrainingPhaseResult, plan_transformer_split)
from repro.core.profiler import (GTX_1080TI, JETSON_TX2, PAPER_CLOUD_ONLY,
                                 PAPER_MOBILE_ONLY, paper_profiles)
from repro.core.wireless import INTER_POD, NETWORKS


# Table V: chosen partitions per network (latency AND energy agree)
PAPER_SELECTIONS = {"3g": 8, "4g": 1, "wifi": 1}


@pytest.mark.parametrize("net", ["3g", "4g", "wifi"])
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_selection_reproduces_table5(net, objective):
    profile = paper_profiles()[net]
    assert select_from_table(profile, objective) == PAPER_SELECTIONS[net]


def test_headline_improvements_match_paper():
    """77x/40x/41x latency and 80x/54x/71x energy vs cloud-only (Sec III-B)."""
    profs = paper_profiles()
    expect_lat = {"3g": 77, "4g": 40, "wifi": 41}
    expect_en = {"3g": 80, "4g": 54, "wifi": 71}
    for net in NETWORKS:
        sel = PAPER_SELECTIONS[net]
        lat_x = PAPER_CLOUD_ONLY[net][0] / profs[net][sel]["latency_ms"]
        en_x = PAPER_CLOUD_ONLY[net][1] / profs[net][sel]["energy_mj"]
        assert round(lat_x) == expect_lat[net], (net, lat_x)
        assert round(en_x) == expect_en[net], (net, en_x)


def test_training_phase_linear_search():
    """Minimal D_r found per split; monotone accuracy in D_r assumed."""
    acc = {(1, 1): 0.75, (1, 2): 0.76,
           (2, 1): 0.70, (2, 2): 0.73, (2, 3): 0.745,
           (3, 1): 0.60, (3, 2): 0.65, (3, 3): 0.70, (3, 4): 0.75}

    def train_eval(split, d_r):
        return acc.get((split, d_r), 0.0)

    res = training_phase([1, 2, 3], {1: 8, 2: 8, 3: 8}, train_eval,
                         accuracy_target=0.76, max_loss=0.02)
    assert [(r.split, r.d_r) for r in res] == [(1, 1), (2, 3), (3, 4)]


def test_profiling_and_selection_roofline():
    trained = [TrainingPhaseResult(1, 1, 0.75), TrainingPhaseResult(8, 5, 0.74)]

    def costs(split, d_r):
        # deeper split: more edge flops, less wire
        edge = 1e9 * split
        cloud = 1e9 * (16 - split)
        wire = 4000 // split
        return edge, edge / 10, cloud, cloud / 10, wire

    profs = profiling_phase(trained, costs, JETSON_TX2, GTX_1080TI)
    sel3g = selection_phase(profs, NETWORKS["3g"], "latency")
    selwifi = selection_phase(profs, NETWORKS["wifi"], "latency")
    # slow uplink -> deeper split wins; fast uplink -> shallow split wins
    assert sel3g.split == 8
    assert selwifi.split == 1


def test_congestion_shifts_selection():
    """Paper Sec III-C: cloud congestion pushes the split deeper."""
    trained = [TrainingPhaseResult(j, 2, 0.75) for j in (1, 8)]

    def costs(split, d_r):
        edge = 5e8 * split
        cloud = 5e9 * (16 - split)
        wire = 3000 if split == 1 else 1000
        return edge, 0, cloud, 0, wire

    free = profiling_phase(trained, costs, JETSON_TX2, GTX_1080TI, cloud_load=0.0)
    congested = profiling_phase(trained, costs, JETSON_TX2, GTX_1080TI,
                                cloud_load=0.97)
    net = NETWORKS["wifi"]
    assert selection_phase(free, net).split == 1
    assert selection_phase(congested, net).split == 8


def test_plan_transformer_split_runs():
    from repro.configs import get_config
    from repro.core.profiler import TPU_V5E
    cfg = get_config("qwen3-8b")
    best, rows = plan_transformer_split(
        cfg, seq=1024, batch=8, edge=TPU_V5E, cloud=TPU_V5E,
        interconnect=INTER_POD, d_r=256,
        candidate_splits=[1, 4, 12, 24, 35])
    assert len(rows) == 5
    assert best["split"] in {1, 4, 12, 24, 35}
    assert all(r["compression"] > 1 for r in rows)
