"""Version-compatibility shims for the jax API surface we depend on.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (and its
``check_rep`` flag was renamed ``check_vma``) after 0.4.x; this repo runs on
both sides of that line.  Callers use :func:`shard_map` below with the *new*
spelling and the shim translates for old runtimes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None) -> Any:
    """``jax.shard_map`` with the post-0.4 keyword surface on any jax.

    ``check_vma=None`` means "library default" on either version.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
