"""Flight recorder: virtual-clock span tracing, time-series metrics,
jit profiling, and the perf ratchet (DESIGN.md section 14).

The load-bearing invariants:
  * tracing and metrics are pure observers — a run with both enabled
    produces byte-identical telemetry JSON to a run with both off
  * record -> replay produces byte-identical Chrome trace files
  * the trace validates against the trace-event schema: matched b/e
    pairs, non-overlapping X spans per serial track, >=4 track types
  * every request's spans nest inside its [arrival, done] window, and
    sum(breakdown) == latency exactly, under both decode transports
  * the aggregate.py ratchet passes on the checked-in trajectory and
    fails on a synthetically inflated p95
  * aggregate.py's KNOWN_SCHEMA_VERSIONS (duplicated so CI can run it
    without PYTHONPATH=src) stays in sync with telemetry.SCHEMA_VERSION
"""
import dataclasses
import json
import math
import os
import sys

import pytest

from repro.configs import get_config
from repro.runtime.clock import EventLoop
from repro.runtime.metrics import (CountersView, JitProfiler, MetricsRegistry,
                                   MetricsSampler, read_metrics_jsonl)
from repro.runtime.simulator import (CellSpec, SimConfig, Simulation,
                                     trace_arrivals)
from repro.runtime.telemetry import SCHEMA_VERSION, Telemetry
from repro.runtime.tracing import (NULL_TRACER, Tracer, validate_chrome_trace)

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


MIXED = (CellSpec(name="3g0", network="3g", num_devices=4, device="jetson"),
         CellSpec(name="wifi1", network="wifi", num_devices=4,
                  device="phone"))


def topo_cfg(**kw):
    defaults = dict(topology=MIXED, adapt=True, transport="auto",
                    num_requests=24, max_new_tokens=4,
                    background_load=lambda t: 0.5)
    defaults.update(kw)
    return timing_cfg(**defaults)


# ---------------------------------------------------------------- tracing

def test_traced_topology_validates_chrome_schema():
    sim = Simulation(topo_cfg(trace=True))
    sim.run()
    doc = json.loads(sim.tracer.to_json())
    assert doc["otherData"]["schema_version"] == 1
    stats = validate_chrome_trace(doc, min_track_types=4)
    # edge + wire + cloud + ctl (+ slot) all present
    assert stats["track_types"] >= 4
    assert stats["X"] > 0 and stats["b"] > 0 and stats["i"] > 0


def test_trace_record_replay_byte_identical(tmp_path):
    path = str(tmp_path / "arrivals.jsonl")
    sim1 = Simulation(topo_cfg(trace=True))
    sim1.record_trace(path)
    sim1.run()
    sim2 = Simulation(topo_cfg(trace=True, arrivals=trace_arrivals(path)))
    sim2.run()
    assert sim1.tracer.to_json() == sim2.tracer.to_json()


def test_tracing_and_metrics_are_pure_observers():
    """The regression test for the opt-out: a timing-only sim with the
    flight recorder fully enabled must produce telemetry byte-identical
    to one with it off."""
    plain = Simulation(timing_cfg()).run().to_json()
    observed = Simulation(timing_cfg(trace=True, metrics=True)).run()
    assert observed.to_json() == plain


@pytest.mark.parametrize("transport", ["cache_handoff", "streamed"])
def test_breakdown_sums_and_spans_nest(transport):
    """Property-style: for every request, sum(breakdown) == latency_s, and
    every trace span carrying its uid lies inside [t_arrival, t_done]."""
    sim = Simulation(topo_cfg(transport=transport, adapt=False,
                              background_load=None, max_new_tokens=4,
                              trace=True))
    tel = sim.run()
    assert len(tel.traces) == 24
    for t in tel.traces:
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)
    doc = json.loads(sim.tracer.to_json())
    validate_chrome_trace(doc)  # per-track X spans do not overlap
    window = {t.uid: (t.t_arrival * 1e6, t.t_done * 1e6)
              for t in tel.traces}
    checked = 0
    for ev in doc["traceEvents"]:
        uid = ev.get("args", {}).get("uid")
        if uid is None or ev["ph"] not in ("X",):
            continue
        lo, hi = window[uid]
        eps = 1e-3  # microsecond rounding
        assert ev["ts"] >= lo - eps
        assert ev["ts"] + ev["dur"] <= hi + eps
        checked += 1
    assert checked > 0


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.complete("t", "x", 0.0, 1.0)
    NULL_TRACER.instant("t", "x", 0.0)
    NULL_TRACER.async_span("t", "x", 1, 0.0, 1.0)
    assert Tracer().enabled


def test_validator_rejects_overlap_and_unmatched_async():
    tr = Tracer()
    tr.complete("edge/c/d0", "a", 0.0, 2.0)
    tr.complete("edge/c/d0", "b", 1.0, 3.0)  # overlaps on one serial track
    with pytest.raises(ValueError, match="overlap"):
        validate_chrome_trace(json.loads(tr.to_json()), min_track_types=1)
    tr2 = Tracer()
    tr2.events.append({"ph": "b", "name": "q", "cat": "req", "id": "1",
                       "pid": 1, "tid": 1, "ts": 0.0})
    with pytest.raises(ValueError, match="unmatched"):
        validate_chrome_trace(json.loads(tr2.to_json()), min_track_types=0)


# ---------------------------------------------------------------- metrics

def test_metrics_sampler_timeline(tmp_path):
    sim = Simulation(topo_cfg(metrics=True, metrics_interval_s=0.02))
    sim.run()
    rows = sim.sampler.rows
    assert len(rows) >= 2
    ts = [r["t"] for r in rows]
    assert ts == sorted(ts)
    names = set(sim.sampler.sources)
    assert {"cloud/load", "cell/3g0/queue_depth", "cell/wifi1/in_flight",
            "wire/3g0/up_goodput_bps"} <= names
    for r in rows:
        assert set(r) == names | {"t"}
    path = str(tmp_path / "metrics.jsonl")
    sim.sampler.write(path)
    assert read_metrics_jsonl(path) == rows


def test_counters_view_backcompat():
    """Telemetry.counters migrated onto MetricsRegistry but must keep
    behaving like the old defaultdict(float)."""
    tel = Telemetry()
    tel.counters["prefill_batches"] += 1
    tel.counters["prefill_batches"] += 2
    tel.counters["decode_turns"] = 5
    assert tel.counters["prefill_batches"] == 3.0
    assert tel.counters["never_touched"] == 0.0
    assert dict(tel.counters)["decode_turns"] == 5.0
    assert isinstance(tel.counters, CountersView)
    # and it is a live view, not a copy
    assert tel.registry.counter("decode_turns").value == 5.0


def test_registry_histogram_and_gauge():
    reg = MetricsRegistry()
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("lat").observe(v)
    s = reg.histogram("lat").summary()
    assert s["count"] == 4 and s["p50"] == pytest.approx(2.5)
    reg.gauge("depth").set(7)
    assert reg.to_dict()["gauges"]["depth"] == 7.0


def test_schedule_every_cancel():
    loop = EventLoop()
    seen = []
    cancel = loop.schedule_every(0.1, lambda: seen.append(loop.now))
    loop.schedule(0.35, cancel)
    loop.schedule(1.0, lambda: None)  # keep the loop alive past the cancel
    loop.run()
    assert len(seen) == 3  # 0.1, 0.2, 0.3 — nothing after cancel


def test_throughput_nan_for_zero_span():
    """A zero-width request span has no defined rate: nan (was inf), so
    JSON consumers render it as missing instead of blowing up."""
    from repro.runtime.telemetry import RequestTrace
    tel = Telemetry()
    tel.traces.append(RequestTrace(uid=0, device=0, mode="split",
                                   wire_mode="int8", split=1, prompt_len=4))
    assert math.isnan(tel.summary()["throughput_rps"])
    real = Simulation(timing_cfg()).run()
    assert real.summary()["throughput_rps"] > 0
    assert json.loads(real.to_json())["schema_version"] == SCHEMA_VERSION


# ---------------------------------------------------------------- jit profile

def test_jit_profile_numerics_smoke():
    cfg = timing_cfg(cfg=small_cfg(layers=2), numerics=True, num_requests=3,
                     num_devices=2, prompt_len=8, max_new_tokens=2,
                     profile_jit=True)
    sim = Simulation(cfg)
    tel = sim.run()
    assert tel.jit_profile is not None
    h = tel.jit_profile["headline"]
    assert h["entries"] > 0 and h["calls"] >= h["entries"]
    assert 0.0 <= h["compile_fraction"] <= 1.0
    assert tel.counters["bank_jit_cache_misses"] > 0
    # profile rides in telemetry JSON only when enabled
    assert "jit_profile" in json.loads(tel.to_json())
    plain = Simulation(timing_cfg()).run()
    assert plain.jit_profile is None
    assert "jit_profile" not in json.loads(plain.to_json())


def test_jit_profiler_first_vs_steady():
    prof = JitProfiler()
    for _ in range(3):
        prof.timed(("k", 1), lambda x: x + 1, 1)
    assert prof.first_calls == 1 and prof.steady_calls == 2
    assert prof.summary()["k/1"]["calls"] == 3


# ---------------------------------------------------------------- ratchet

def _aggregate():
    sys.path.insert(0, EXPERIMENTS)
    try:
        import aggregate
    finally:
        sys.path.pop(0)
    return aggregate


def test_schema_version_crosscheck():
    """aggregate.py duplicates the known schema versions on purpose (the CI
    runtime-table job runs without PYTHONPATH=src); this is the sync
    check."""
    agg = _aggregate()
    assert SCHEMA_VERSION in agg.KNOWN_SCHEMA_VERSIONS


def test_ratchet_passes_on_checked_in_trajectory():
    agg = _aggregate()
    doc = json.load(open(os.path.join(EXPERIMENTS, "BENCH_runtime.json")))
    runs = doc["runs"]
    assert len(runs) >= 2
    report = agg.check_regression(runs[-1], runs)
    # the fresh run itself is excluded from the baselines by content
    assert report["baseline_runs"] == len(runs) - 1
    assert report["checked"] > 0
    assert report["violations"] == []


def test_ratchet_fails_on_inflated_p95():
    import copy
    agg = _aggregate()
    runs = json.load(
        open(os.path.join(EXPERIMENTS, "BENCH_runtime.json")))["runs"]
    bad = copy.deepcopy(runs[-1])
    bad["networks"]["3g"]["split_int8"]["latency_p95_ms"] *= 1.2
    report = agg.check_regression(bad, runs)
    keys = [v["key"] for v in report["violations"]]
    assert "networks.3g.split_int8.latency_p95_ms" in keys
    # higher-is-better direction: a throughput drop is also caught
    bad2 = copy.deepcopy(runs[-1])
    bad2["networks"]["3g"]["split_int8"]["throughput_rps"] *= 0.5
    report2 = agg.check_regression(bad2, runs)
    assert any("throughput_rps" in v["key"] for v in report2["violations"])


def test_ratchet_direction_inference():
    agg = _aggregate()
    assert agg._direction("networks.3g.split_int8.latency_p95_ms") == -1
    assert agg._direction("networks.3g.split_speedup_vs_cloud") == 1
    assert agg._direction("x.throughput_rps") == 1
    assert agg._direction("workload.requests") == 0  # not ratcheted
    assert agg._direction("adaptive.split_at_high_load") == 0
