"""Byte-level tokenizer (vocab 256 + specials), enough for the runnable
examples without external assets."""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 259


def encode(text: str, add_bos: bool = True) -> np.ndarray:
    ids = list(text.encode("utf-8"))
    if add_bos:
        ids = [BOS] + ids
    return np.asarray(ids, np.int32)


def decode(ids) -> str:
    ids = [int(i) for i in np.asarray(ids).ravel() if int(i) < 256]
    return bytes(ids).decode("utf-8", errors="replace")
