"""The butterfly unit (the paper's Section II-A), adapted per DESIGN.md:

  reduction unit  : learned projection  d -> d_r   (edge side)
  wire            : int8 symmetric quantization (+ f32 scales)
  restoration unit: learned projection  d_r -> d   (cloud side)

For the transformer architectures ``d`` is d_model and the unit acts on the
residual stream at a layer boundary; a 1x1 conv over NHWC (the paper's
ResNet form, models/resnet.py) is exactly the same per-position linear map.

The unit is trained end-to-end inside the host model (``fake_quant`` is a
straight-through estimator), and at serving time the reduce+quantize half
runs on the edge stage while dequantize+restore runs on the cloud stage
(serving/pipeline.py), with only (codes, scales) crossing the pod boundary.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ButterflyConfig
from repro.core.quantization import dequantize, fake_quant, quantize, wire_bytes
from repro.models.common import dense_init


def init_butterfly(key, d: int, bf: ButterflyConfig, dtype):
    k1, k2 = jax.random.split(key)
    params = {
        "w_reduce": dense_init(k1, d, bf.d_r, dtype),
        "w_restore": dense_init(k2, bf.d_r, d, dtype, scale=1.0 / bf.d_r),
    }
    specs = {"w_reduce": P(None, None), "w_restore": P(None, None)}
    return params, specs


def reduce_unit(params, x: jax.Array, *, use_kernel: bool = False,
                wire_bits: int = 8):
    """Edge half: project + quantize.  Returns (codes, scales)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.butterfly_reduce_quant(x, params["w_reduce"], bits=wire_bits)
    r = x @ params["w_reduce"]
    return quantize(r, wire_bits)


def restore_unit(params, codes: jax.Array, scales: jax.Array, dtype,
                 *, use_kernel: bool = False):
    """Cloud half: dequantize + project back to d."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.butterfly_dequant_restore(codes, scales,
                                              params["w_restore"],
                                              out_dtype=dtype)
    r = dequantize(codes, scales, dtype)
    return r @ params["w_restore"]


def apply_butterfly(params, x: jax.Array, *, wire_bits: int = 8,
                    train: bool = True, use_kernel: bool = False) -> jax.Array:
    """In-graph form (training / single-mesh inference): the wire is a
    fake-quant so gradients flow straight through (paper: trained
    end-to-end).  With ``train=False, use_kernel=True`` the quantized wire
    runs through the fused Pallas reduce+quant / dequant+restore kernels
    (the serving hot path; a (B, 1, d) decode row takes the kops fast path)."""
    if not train and use_kernel and wire_bits <= 8:
        from repro.kernels import ops as kops
        codes, scales = kops.butterfly_reduce_quant(x, params["w_reduce"],
                                                    bits=wire_bits)
        return kops.butterfly_dequant_restore(codes, scales,
                                              params["w_restore"],
                                              out_dtype=x.dtype)
    r = x @ params["w_reduce"]
    if train:
        r = fake_quant(r, wire_bits)
    else:
        codes, scales = quantize(r, wire_bits)
        r = dequantize(codes, scales, x.dtype)
    return r @ params["w_restore"]


def butterfly_wire_bytes(batch: int, seq: int, d_r: int, wire_bits: int = 8) -> int:
    return wire_bytes((batch, seq, d_r), wire_bits)


def compression_ratio(d: int, d_r: int, act_bits: int, wire_bits: int = 8) -> float:
    """Feature-volume compression vs. shipping the raw boundary tensor."""
    return (d * act_bits) / (d_r * wire_bits)
