"""Training launcher.

Two modes:
  * local (default): really train a (reduced or custom) config on the
    synthetic LM pipeline on the available devices — the end-to-end driver.
  * --lower-only: AOT-lower the full config's train step on the production
    mesh (512 host devices) and print memory/cost analysis (the dry-run path
    for one arch; see launch/dryrun.py for the sweep).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 200 --batch 16 --seq 128 --butterfly-layer 1 --d-r 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0, help="override vocab (synthetic data)")
    ap.add_argument("--butterfly-layer", type=int, default=None)
    ap.add_argument("--d-r", type=int, default=32)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.lower_only:
        # delegate to the dry-run (sets device count before jax init)
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_pair
        run_pair(args.arch, "train_4k", args.multi_pod, "experiments/dryrun")
        return

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import lm_batches
    from repro.models import model as M
    from repro.training import (AdamWConfig, adamw_init, cosine_schedule,
                                make_train_step)
    from repro.training.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)
    if args.butterfly_layer is not None:
        cfg = cfg.with_butterfly(args.butterfly_layer, args.d_r)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M butterfly={cfg.butterfly}")

    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=cosine_schedule(args.lr, 20, args.steps))
    step_fn = jax.jit(make_train_step(built, opt_cfg))
    stream = lm_batches(cfg.vocab_size, args.seq, args.batch)

    t0 = time.time()
    for i, raw in zip(range(args.steps), stream):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.num_patches:
            batch["patches"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
            batch["targets"] = jnp.concatenate(
                [jnp.full((args.batch, cfg.num_patches), -1, jnp.int32),
                 batch["targets"]], axis=1)
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros((args.batch, cfg.encoder_frames,
                                         cfg.d_model), jnp.dtype(cfg.dtype))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            tput = (i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"tok/s {tput:,.0f}")
    if args.checkpoint:
        path = save_checkpoint(args.checkpoint, params, opt_state,
                               step=args.steps, metadata={"arch": cfg.name})
        print("saved", path)


if __name__ == "__main__":
    main()
