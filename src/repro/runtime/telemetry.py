"""Per-request traces and fleet-level aggregates for the split runtime.

Every request records absolute virtual timestamps at each hop; the breakdown
(edge queue / edge compute / uplink / cloud queue / cloud compute) is derived
so the invariant ``sum(breakdown) == latency`` holds by construction and is
asserted in tests.  Aggregates report p50/p95/p99 latency, wire bytes, and
mobile energy — the paper's Table V quantities at request-stream scale.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.runtime.metrics import MetricsRegistry

# version of the ``to_json()`` document layout.  Bumped when keys move or
# change meaning so downstream consumers (the CI runtime-table job, the
# aggregate.py perf ratchet) can reject drift explicitly instead of
# misreading a stale schema.  v2 = adds schema_version itself + the
# registry-backed counters + optional jit_profile section.  v3 = request
# outcomes (outcome/failure/retries/migrations/fallback), latency
# aggregates partitioned to completed requests, availability/failure
# counts in the summary, and the controller decision reason.  v4 = the
# serving gateway (runtime/gateway.py): per-request SLO class
# (slo_class/hedges/cache_hit on the trace), the "shed" outcome with
# conservation counts (n_done + n_failed + n_shed == n_requests), and the
# per-class "classes" aggregate section.  v5 = the entropy-coded wire
# (core/wire_codec.py): per-trace coded_bytes/nominal_bytes accounting plus
# the summary's compression_ratio / mean_coded_bytes_per_token — the trace
# fields are zero and the summary keys absent outside wire_mode="entropy".
SCHEMA_VERSION = 5


@dataclass
class RequestTrace:
    uid: int
    device: int
    mode: str                          # split | cloud | edge
    wire_mode: str                     # raw | reduced | int8 (split mode)
    split: int                         # partition point used (0 = no split)
    prompt_len: int
    cell: str = "cell0"                # topology cell that emitted the request
    transport: str = "cache_handoff"   # decode transport (split mode)
    new_tokens: int = 0
    wire_bytes: float = 0.0            # uplink bytes (codes, cache, rows)
    downlink_bytes: float = 0.0        # sampled token ids back to the mobile
    # entropy-wire accounting (schema v5) — both stay 0.0 outside
    # wire_mode="entropy", so fixed-rate runs serialize identically modulo
    # the keys.  coded counts the rANS prefill payloads actually charged to
    # the wire (real encoder size in numerics mode, the nominal-rate
    # prediction in timing-only runs); nominal is the int8 fixed-rate
    # equivalent of those same payloads, so nominal/coded is the codec gain
    coded_bytes: float = 0.0
    nominal_bytes: float = 0.0
    mobile_energy_mj: float = 0.0
    # streamed-decode loop accounting (one entry per generated token after
    # the first: edge step -> row uplink -> cloud turn -> token downlink)
    stream_steps: int = 0
    stream_rtt_s: float = 0.0          # total row-sent -> token-back time
    # absolute virtual timestamps (seconds)
    t_arrival: float = 0.0
    t_edge_start: float = 0.0
    t_edge_done: float = 0.0
    t_uplink_start: float = 0.0        # transfer admitted to the link
    t_uplink_done: float = 0.0
    t_cloud_start: float = 0.0         # admitted into the batch server
    t_first_token: float = 0.0
    t_cloud_done: float = 0.0          # cloud's last involvement
    t_done: float = 0.0                # response fully at the mobile
    # fault/recovery outcome (schema v3) — all defaults describe the
    # no-fault world, so calm runs serialize identically modulo the keys
    outcome: str = "done"              # done | failed | shed
    failure: str = ""                  # reason when outcome != "done"
    retries: int = 0                   # timeout-driven resends
    migrations: int = 0                # device-to-device migrations
    fallback: str = ""                 # "edge" when degraded to edge-only
    # serving-gateway fields (schema v4) — defaults describe the
    # no-gateway world, same contract as the fault block above
    slo_class: str = "interactive"     # interactive | batch
    hedges: int = 0                    # duplicate payload sends raced
    cache_hit: bool = False            # served from the LRU response cache

    # -- derived breakdown --------------------------------------------------
    @property
    def edge_queue_s(self) -> float:
        return self.t_edge_start - self.t_arrival

    @property
    def edge_compute_s(self) -> float:
        return self.t_edge_done - self.t_edge_start

    @property
    def uplink_wait_s(self) -> float:
        return self.t_uplink_start - self.t_edge_done

    @property
    def uplink_s(self) -> float:
        return self.t_uplink_done - self.t_uplink_start

    @property
    def cloud_queue_s(self) -> float:
        return self.t_cloud_start - self.t_uplink_done

    @property
    def cloud_s(self) -> float:
        """Cloud phase: prefill + decode turns (for the streamed transport
        this window interleaves edge steps, row uplinks and token downlinks;
        ``stream_rtt_s``/``mean_stream_rtt`` expose the per-token loop)."""
        return self.t_cloud_done - self.t_cloud_start

    @property
    def downlink_s(self) -> float:
        """Final response downlink (the whole id batch for cache handoff,
        the last streamed token for streamed decode)."""
        return self.t_done - self.t_cloud_done

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def ttft_s(self) -> float:
        return self.t_first_token - self.t_arrival

    def clamp_chain(self) -> None:
        """Forward-max the timestamp chain so every derived phase is
        non-negative and ``sum(breakdown) == latency`` holds even for
        requests that failed, fell back, or skipped phases (a request that
        never reached the cloud leaves those legs at exactly zero).  A
        monotone chain is untouched, so calling this on a normally
        completed request is a byte-exact no-op."""
        prev = self.t_arrival
        for name in ("t_edge_start", "t_edge_done", "t_uplink_start",
                     "t_uplink_done", "t_cloud_start", "t_cloud_done",
                     "t_done"):
            v = getattr(self, name)
            if v < prev:
                setattr(self, name, prev)
            else:
                prev = v

    def breakdown(self) -> Dict[str, float]:
        return {
            "edge_queue_s": self.edge_queue_s,
            "edge_compute_s": self.edge_compute_s,
            "uplink_wait_s": self.uplink_wait_s,
            "uplink_s": self.uplink_s,
            "cloud_queue_s": self.cloud_queue_s,
            "cloud_s": self.cloud_s,
            "downlink_s": self.downlink_s,
        }


def percentile(values: List[float], p: float) -> float:
    """Deterministic linear-interpolation percentile (numpy 'linear')."""
    if not values:
        return float("nan")
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    frac = rank - lo
    return xs[lo] * (1 - frac) + xs[hi] * frac


@dataclass
class ControlDecision:
    t: float
    cloud_load: float
    link_bytes_per_s: float
    old_split: int
    new_split: int
    transport: str = "cache_handoff"   # decode transport picked alongside
    cell: str = "cell0"                # which cell's controller decided
    reason: str = "tick"               # tick | handover | ... (why now)


class Telemetry:
    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.traces: List[RequestTrace] = []
        self.decisions: List[ControlDecision] = []
        # free-form runtime counters (numerics batch sizes, decode steps,
        # compile-cache entries ...) — populated by the actors/simulator.
        # Backed by the metrics registry so the same numbers are scrapeable
        # next to gauges/histograms; the view keeps the defaultdict(float)
        # semantics every call site relies on.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.counters = self.registry.counters
        # wall-clock jit attribution (JitProfiler.summary()+headline());
        # opt-in and host-dependent, so only set when SimConfig.profile_jit
        self.jit_profile: Optional[Dict[str, object]] = None

    def record(self, trace: RequestTrace) -> None:
        self.traces.append(trace)

    def record_decision(self, d: ControlDecision) -> None:
        self.decisions.append(d)

    # -- aggregates ---------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        # latency/ttft/breakdown/throughput aggregate the *completed*
        # requests only — a fast failure must not improve p95.  Byte,
        # energy, and RTT totals cover every request (failed ones still
        # burned radio).  In a no-fault run done == traces, so every
        # pre-existing number is unchanged.
        done = [t for t in self.traces if t.outcome == "done"]
        lat = [t.latency_s for t in done]
        ttft = [t.ttft_s for t in done]
        out: Dict[str, float] = {"n_requests": len(self.traces)}
        for name, xs in (("latency", lat), ("ttft", ttft)):
            for p in (50, 95, 99):
                out[f"{name}_p{p}_ms"] = percentile(xs, p) * 1e3
            out[f"{name}_mean_ms"] = (sum(xs) / len(xs) * 1e3) if xs else float("nan")
        if self.traces:
            for key in ("edge_queue_s", "edge_compute_s", "uplink_wait_s",
                        "uplink_s", "cloud_queue_s", "cloud_s", "downlink_s"):
                out[f"mean_{key[:-2]}_ms"] = (sum(
                    t.breakdown()[key] for t in done) / len(done) * 1e3) \
                    if done else float("nan")
            out["total_wire_mb"] = sum(t.wire_bytes for t in self.traces) / 1e6
            out["mean_wire_kb"] = sum(
                t.wire_bytes for t in self.traces) / len(self.traces) / 1e3
            out["total_downlink_kb"] = sum(
                t.downlink_bytes for t in self.traces) / 1e3
            out["mean_downlink_b"] = sum(
                t.downlink_bytes for t in self.traces) / len(self.traces)
            steps = sum(t.stream_steps for t in self.traces)
            out["mean_stream_rtt_ms"] = (sum(
                t.stream_rtt_s for t in self.traces) / steps * 1e3) if steps \
                else 0.0
            out["mean_mobile_energy_mj"] = sum(
                t.mobile_energy_mj for t in self.traces) / len(self.traces)
            # entropy-wire aggregates (schema v5): emitted only when some
            # trace carried a coded payload — fixed-rate runs keep their
            # exact pre-v5 summary (and nan never enters dict comparisons)
            coded = sum(t.coded_bytes for t in self.traces)
            if coded > 0:
                nominal = sum(t.nominal_bytes for t in self.traces)
                ctoks = sum(t.prompt_len for t in self.traces
                            if t.coded_bytes > 0)
                out["compression_ratio"] = nominal / coded
                out["mean_coded_bytes_per_token"] = coded / ctoks \
                    if ctoks > 0 else float("nan")
            span = (max(t.t_done for t in done) -
                    min(t.t_arrival for t in done)) if done else 0.0
            # span == 0 (single request, or all requests at one instant)
            # has no defined rate — nan, not inf, so JSON consumers and
            # the aggregate table render it as missing rather than blowing
            # up comparisons
            out["throughput_rps"] = len(done) / span if span > 0 \
                else float("nan")
            # outcome counts (schema v3): availability counts degraded
            # edge-fallback completions as served — they got an answer.
            # Shed (v4) partitions out of failed: the gateway REFUSED these
            # by policy, it did not lose them — and the three outcomes are
            # conserved: n_done + n_failed + n_shed == n_requests.
            shed = sum(1 for t in self.traces if t.outcome == "shed")
            out["n_done"] = len(done)
            out["n_failed"] = len(self.traces) - len(done) - shed
            out["n_shed"] = shed
            out["n_migrated"] = sum(1 for t in self.traces if t.migrations)
            out["n_retried"] = sum(1 for t in self.traces if t.retries)
            out["n_fallback"] = sum(1 for t in self.traces if t.fallback)
            out["n_hedged"] = sum(1 for t in self.traces if t.hedges)
            out["n_cache_hits"] = sum(1 for t in self.traces if t.cache_hit)
            out["availability_pct"] = 100.0 * len(done) / len(self.traces)
        return out

    def class_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-SLO-class aggregates (schema v4): latency percentiles over
        the completed requests of each class plus its outcome counts — the
        view the gateway benchmark's shed-on/shed-off comparison reads."""
        out: Dict[str, Dict[str, float]] = {}
        classes: List[str] = []
        for t in self.traces:
            if t.slo_class not in classes:
                classes.append(t.slo_class)
        for cls in classes:
            ts = [t for t in self.traces if t.slo_class == cls]
            done = [t for t in ts if t.outcome == "done"]
            shed = sum(1 for t in ts if t.outcome == "shed")
            lat = [t.latency_s for t in done]
            out[cls] = {
                "n_requests": len(ts),
                "n_done": len(done),
                "n_failed": len(ts) - len(done) - shed,
                "n_shed": shed,
                "latency_p50_ms": percentile(lat, 50) * 1e3,
                "latency_p95_ms": percentile(lat, 95) * 1e3,
                "latency_p99_ms": percentile(lat, 99) * 1e3,
                "latency_mean_ms": (sum(lat) / len(lat) * 1e3) if lat
                else float("nan"),
            }
        return out

    def split_trajectory(self) -> List[Dict[str, float]]:
        return [{"t": d.t, "cloud_load": d.cloud_load,
                 "link_bytes_per_s": d.link_bytes_per_s,
                 "split": d.new_split, "transport": d.transport,
                 "cell": d.cell, "reason": d.reason}
                for d in self.decisions]

    # -- per-cell aggregates / fairness -------------------------------------
    @property
    def cells(self) -> List[str]:
        """Cell names in first-trace order (stable across replays)."""
        seen: List[str] = []
        for t in self.traces:
            if t.cell not in seen:
                seen.append(t.cell)
        return seen

    def cell_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-cell latency/energy/bytes aggregates — the per-cell view the
        fairness report (and the topology benchmark) is built from."""
        out: Dict[str, Dict[str, float]] = {}
        for cell in self.cells:
            ts = [t for t in self.traces if t.cell == cell]
            done = [t for t in ts if t.outcome == "done"]
            shed = sum(1 for t in ts if t.outcome == "shed")
            lat = [t.latency_s for t in done]
            out[cell] = {
                "n_requests": len(ts),
                "n_failed": len(ts) - len(done) - shed,
                "n_shed": shed,
                "latency_p50_ms": percentile(lat, 50) * 1e3,
                "latency_p95_ms": percentile(lat, 95) * 1e3,
                "latency_mean_ms": (sum(lat) / len(lat) * 1e3) if lat
                else float("nan"),
                "mean_uplink_wait_ms": (sum(
                    t.uplink_wait_s for t in done) / len(done) * 1e3)
                if done else float("nan"),
                "mean_wire_kb": sum(t.wire_bytes for t in ts) / len(ts) / 1e3,
                "downlink_kb": sum(t.downlink_bytes for t in ts) / 1e3,
                "mean_mobile_energy_mj": sum(
                    t.mobile_energy_mj for t in ts) / len(ts),
            }
        return out

    def fairness(self) -> Dict[str, float]:
        """Topology-level fairness across cells: max/min spread of the mean
        and p95 latencies plus Jain's fairness index over per-cell mean
        latency (1.0 = perfectly even service, ->1/n as one cell starves).
        Single-cell runs are trivially fair."""
        cells = self.cell_summary()
        # a cell whose every request failed has nan latencies — it cannot
        # enter the spread/Jain math (nan poisons every comparison)
        means = [c["latency_mean_ms"] for c in cells.values()
                 if math.isfinite(c["latency_mean_ms"])]
        p95s = [c["latency_p95_ms"] for c in cells.values()
                if math.isfinite(c["latency_p95_ms"])]
        if not means or not p95s:
            return {}
        sq = sum(m * m for m in means)
        return {
            "n_cells": len(means),
            "max_min_latency_ratio": max(means) / max(min(means), 1e-12),
            "p95_spread_ms": max(p95s) - min(p95s),
            "jain_index": (sum(means) ** 2) / max(len(means) * sq, 1e-12),
        }

    # -- rendering ----------------------------------------------------------
    _COLS = ("uid", "dev", "cell", "split", "tport", "S", "edgeq_ms",
             "edge_ms", "upwait_ms", "uplink_ms", "cloudq_ms", "cloud_ms",
             "dlink_ms", "total_ms", "wire_kb", "down_b", "energy_mj")

    def table(self) -> str:
        """Per-request latency-breakdown table (the CLI's main output)."""
        rows = [" ".join(f"{c:>9s}" for c in self._COLS)]
        for t in self.traces:
            tport = {"streamed": "stream",
                     "progressive": "prgrsv"}.get(t.transport, "handoff")
            vals = (t.uid, t.device, t.cell[:9], t.split, tport,
                    t.prompt_len,
                    t.edge_queue_s * 1e3, t.edge_compute_s * 1e3,
                    t.uplink_wait_s * 1e3, t.uplink_s * 1e3,
                    t.cloud_queue_s * 1e3, t.cloud_s * 1e3,
                    t.downlink_s * 1e3, t.latency_s * 1e3,
                    t.wire_bytes / 1e3, t.downlink_bytes,
                    t.mobile_energy_mj)
            rows.append(" ".join(
                f"{v:>9d}" if isinstance(v, int) else
                f"{v:>9s}" if isinstance(v, str) else f"{v:>9.3f}"
                for v in vals))
        return "\n".join(rows)

    def to_json(self) -> str:
        doc = {
            "schema_version": SCHEMA_VERSION,
            "summary": self.summary(),
            "classes": self.class_summary(),
            "cells": self.cell_summary(),
            "fairness": self.fairness(),
            "counters": dict(self.counters),
            "decisions": self.split_trajectory(),
            "traces": [dict(asdict(t), **{k: round(v, 9) for k, v in
                                          t.breakdown().items()})
                       for t in self.traces],
        }
        if self.jit_profile is not None:
            doc["jit_profile"] = self.jit_profile
        return json.dumps(doc, indent=2, sort_keys=True)
