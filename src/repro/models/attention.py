"""GQA attention with qk-norm, RoPE, sliding windows and ring-buffer KV caches.

Three modes share one code path:
  * ``train``   — full sequence, causal (+ optional window), no cache
  * ``prefill`` — like train but also returns the populated KV cache
  * ``decode``  — one new token per sequence against an existing cache

Caches are ring buffers when a window is set (cache length == window), so the
``long_500k`` shape holds only O(window) keys for windowed layers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, dense_spec, rms_norm

MASK_VALUE = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _padded_heads(cfg: ModelConfig) -> int:
    """Perf iteration pair 2 / iter 3 (EXPERIMENTS.md section Perf): when the
    q-head count does not divide the 16-way model axis, pad to the next
    multiple of 16 that the kv-head count divides.  The padded heads are
    functionally dead (their wo rows init to zero and stay exactly zero under
    weight decay-free norms... they train, but the *initial* function is
    identical and sharding is clean: whole heads per shard, no GSPMD
    reshape all-reduces).  Enabled with REPRO_ATTN_PAD_HEADS=1."""
    import os as _os
    if _os.environ.get("REPRO_ATTN_PAD_HEADS", "0") != "1":
        return cfg.num_heads
    n = cfg.num_heads
    if n % 16 == 0:
        return n
    p = ((n + 15) // 16) * 16
    while p % cfg.num_kv_heads:
        p += 16
    return p


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    n_pad = _padded_heads(cfg)
    wq = dense_init(kq, cfg.d_model, n_pad * hd, dtype)
    wo = dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype,
                    scale=1.0 / (cfg.num_heads * hd))
    if n_pad != cfg.num_heads:
        # dead padded heads: zero wo rows, inserted PER KV GROUP so the
        # (B,S,K,G_pad,hd) grouping keeps each q head with its kv head
        K = cfg.num_kv_heads
        G, G_pad = cfg.num_heads // K, n_pad // K
        wo = wo.reshape(K, G, hd, cfg.d_model)
        pad = jnp.zeros((K, G_pad - G, hd, cfg.d_model), dtype)
        wo = jnp.concatenate([wo, pad], axis=1).reshape(n_pad * hd, cfg.d_model)
    params = {
        "wq": wq,
        "wk": dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": wo,
    }
    # Perf iteration (EXPERIMENTS.md section Perf pair 2): sharding the fused
    # (heads*hd) dim when heads % mesh != 0 leaves 2.5 heads per shard; GSPMD
    # then resolves the (B,S,N,hd) reshape with per-layer all-reduces of
    # f32 score-sized tensors (~1.8 TB/device for qwen3-14b prefill).  Shard
    # head dims only when the *head count* divides the axis; otherwise
    # replicate the attention weights and let batch parallelism carry.
    import os as _os
    head_aware = _os.environ.get("REPRO_ATTN_HEAD_AWARE", "0") == "1"
    q_ok = (not head_aware) or n_pad % 16 == 0
    kv_ok = (not head_aware) or cfg.num_kv_heads % 16 == 0
    specs = {
        "wq": dense_spec((cfg.d_model, n_pad * hd), 1 if q_ok else None),
        "wk": dense_spec((cfg.d_model, cfg.num_kv_heads * hd), 1 if kv_ok else None),
        "wv": dense_spec((cfg.d_model, cfg.num_kv_heads * hd), 1 if kv_ok else None),
        "wo": dense_spec((n_pad * hd, cfg.d_model), 0 if q_ok else None),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((hd,), dtype)
        params["k_norm"] = jnp.zeros((hd,), dtype)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> dict:
    hd = cfg.resolved_head_dim
    shape = (batch, length, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(batch_axis, length_axis=None, head_axis=None) -> dict:
    """``head_axis`` shards the kv-head dim (tensor-parallel stages keep each
    rank's cache slice resident with its attention-head shard)."""
    return {"k": P(batch_axis, length_axis, head_axis, None),
            "v": P(batch_axis, length_axis, head_axis, None)}


def tp_attention_specs(cfg: ModelConfig, axis: str = "model") -> dict:
    """Megatron-style specs for one attention param set sharded over a model
    axis: fused q/k/v projections column-parallel (whole heads per shard),
    the out projection row-parallel — its partial outputs are psum'd by
    ``apply_layer``.  Requires whole-head divisibility, asserted by
    :func:`check_tp_divisibility` at spec-build time."""
    specs = {"wq": P(None, axis), "wk": P(None, axis), "wv": P(None, axis),
             "wo": P(axis, None)}
    if cfg.qk_norm:
        specs["q_norm"] = P(None)          # per-head-dim, replicated
        specs["k_norm"] = P(None)
    return specs


# ---------------------------------------------------------------------------
# core math
# ---------------------------------------------------------------------------


def _project_qkv(params, x, cfg: ModelConfig, positions, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    # head counts come from the param shapes, not cfg: inside a shard_map
    # body each model rank holds a whole-head slice of wq/wk/wv (and the
    # padded-head variant widens wq), so cfg.num_heads is the *global* count
    n_q = params["wq"].shape[1] // hd
    n_kv = params["wk"].shape[1] // hd
    q = (x @ params["wq"]).reshape(B, S, n_q, hd)
    k = (x @ params["wk"]).reshape(B, S, n_kv, hd)
    v = (x @ params["wv"]).reshape(B, S, n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,S,N,hd) -> grouped (B,S,K,G,hd); scores (B,K,G,S,T)."""
    B, S, N, hd = q.shape
    K = k.shape[2]
    G = N // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    return scores * (1.0 / math.sqrt(hd))


def _attend(scores, v, mask, dtype):
    scores = jnp.where(mask, scores, MASK_VALUE)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    B, S, K, G, hd = out.shape
    return out.reshape(B, S, K * G, hd).astype(dtype)


def causal_mask(S: int, T: int, offset: int = 0, window: Optional[int] = None):
    """(S, T) boolean mask; query i at absolute position offset+i."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def attention_fullseq(params, x, *, cfg: ModelConfig, window: Optional[int],
                      positions=None, use_kernel: bool = False,
                      causal: bool = True, rope: bool = True):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions, rope=rope)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        scores = _gqa_scores(q, k, cfg)
        if causal:
            mask = causal_mask(S, S, window=window)[None, None, None]
        else:
            mask = jnp.ones((S, S), bool)[None, None, None]
        out = _attend(scores, v, mask, x.dtype)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# decode attention (one token, ring-buffer-aware cache)
# ---------------------------------------------------------------------------


def attention_decode(params, x, cache, cache_pos, *, cfg: ModelConfig,
                     window: Optional[int], rope: bool = True):
    """x: (B,1,d). ``cache_pos`` — absolute position of the new token, either
    an int32 scalar (all sequences aligned: dry-run / batch decode) or an
    (B,) vector (ragged serving engine).  When ``window`` is set the cache
    length equals the window and is used as a ring buffer (slot = p % W)."""
    B, _, _ = x.shape
    T = cache["k"].shape[1]
    pos = jnp.asarray(cache_pos, jnp.int32)
    scalar_pos = pos.ndim == 0
    positions = (jnp.full((B, 1), pos, jnp.int32) if scalar_pos
                 else pos[:, None])
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, rope=rope)
    if scalar_pos:
        # aligned path: dynamic_update_slice shards cleanly under GSPMD
        slot = pos % T if window is not None else pos
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        slots = jnp.broadcast_to(slot, (B,))
    else:
        slots = pos % T if window is not None else jnp.minimum(pos, T - 1)
        b_idx = jnp.arange(B)
        k = cache["k"].at[b_idx, slots].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[b_idx, slots].set(v_new[:, 0].astype(cache["v"].dtype))
    scores = _gqa_scores(q, k, cfg)                      # (B,K,G,1,T)
    idx = jnp.arange(T)[None, :]
    posb = positions                                      # (B,1)
    if window is not None:
        # ring buffer: slot s holds absolute position p iff p % T == s and
        # p <= cache_pos and p > cache_pos - window
        age = (slots[:, None] - idx) % T                  # 0 = newest
        valid = age < jnp.minimum(posb + 1, window)
    else:
        valid = idx <= posb
    out = _attend(scores, v, valid[:, None, None, None, :], x.dtype)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# cross attention (whisper decoder -> encoder states)
# ---------------------------------------------------------------------------


def cross_attention(params, x, enc_kv, *, cfg: ModelConfig):
    """enc_kv: dict(k=(B,F,K,hd), v=...) precomputed from encoder output."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
    scores = _gqa_scores(q, enc_kv["k"], cfg)
    F = enc_kv["k"].shape[1]
    mask = jnp.ones((1, 1, 1, S, F), bool)
    out = _attend(scores, enc_kv["v"], mask, x.dtype)
    out = out.reshape(B, S, -1) @ params["wo"]
    return out


def encoder_kv(params, enc_out, *, cfg: ModelConfig):
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(B, F, cfg.num_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(B, F, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    return {"k": k, "v": v}
