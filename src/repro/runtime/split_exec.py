"""Real numerics + analytic timing for partitioned execution.

Numerics and time are decoupled on purpose: the jax computation produces the
actual logits/tokens/caches (so split serving is verifiable against the
single-mesh forward), while durations come from the roofline
cost model (core/profiler) driven by the deterministic virtual clock — a
CPU-only container can therefore simulate a Jetson-class edge talking to a
GPU-class cloud over 3G with reproducible traces.

The cloud hosts one partitioned model per candidate split (the paper's "M
partitioned models", Sec. III-C); :class:`SplitModelBank` builds them
lazily.  For multi-token requests the edge hands its stage-0 KV cache to the
cloud alongside the codes (prefill/decode-disaggregation style cache
transfer) so decode runs entirely cloud-side; streaming decode over the wire
is the DESIGN.md extension.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core import costs
from repro.core.planner import wire_mode_bytes
from repro.core.profiler import HardwareProfile


def act_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def input_bytes(cfg, seq: int) -> float:
    """Cloud-only offload ships the frontend's feature output (the paper
    ships the raw 224x224x3 image) — one d_model-wide row per position."""
    return float(seq * cfg.d_model * act_bytes(cfg))


# ---------------------------------------------------------------------------
# analytic timing (virtual-clock durations)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostModel:
    cfg: object
    edge: HardwareProfile
    cloud: HardwareProfile

    def _roofline(self, hw: HardwareProfile, flops: float,
                  load: float = 0.0) -> float:
        nbytes = flops / max(self.cfg.d_model, 1)      # planner's bytes proxy
        return hw.latency_s(flops, nbytes) / max(1e-9, 1.0 - load)

    def edge_prefill_s(self, split: int, seq: int, d_r: int) -> float:
        f = costs.stack_flops(self.cfg, seq, 0, split)
        f += 2 * seq * self.cfg.d_model * d_r          # reduction unit
        return self._roofline(self.edge, f)

    def cloud_prefill_s(self, split: int, seq: int, d_r: int,
                        load: float = 0.0) -> float:
        f = costs.stack_flops(self.cfg, seq, split, self.cfg.num_layers)
        f += 2 * seq * d_r * self.cfg.d_model          # restoration unit
        f += costs.embed_flops(self.cfg, seq)
        return self._roofline(self.cloud, f, load)

    def full_prefill_s(self, seq: int, *, where: str,
                       load: float = 0.0) -> float:
        f = costs.stack_flops(self.cfg, seq, 0, self.cfg.num_layers)
        f += costs.embed_flops(self.cfg, seq)
        hw = self.edge if where == "edge" else self.cloud
        return self._roofline(hw, f, load)

    def decode_step_s(self, batch: int, *, where: str,
                      load: float = 0.0) -> float:
        f = costs.model_flops_decode(self.cfg, batch)
        hw = self.edge if where == "edge" else self.cloud
        # decode is weight-bound: every step streams the full parameter set
        nbytes = costs.param_count(self.cfg) * act_bytes(self.cfg)
        return hw.latency_s(f, nbytes) / max(1e-9, 1.0 - load)

    def edge_energy_mj(self, seconds: float) -> float:
        return seconds * self.edge.compute_power_w * 1e3

    def payload_bytes(self, mode: str, wire_mode: str, seq: int,
                      d_r: int, split: int, new_tokens: int = 1) -> float:
        """Uplink bytes per request.  Split requests generating more than one
        token additionally ship the edge stage-0 KV cache (cache handoff —
        counted honestly; avoiding it is the decode-over-the-wire
        extension)."""
        if mode == "cloud":
            return input_bytes(self.cfg, seq)
        if mode == "edge":
            return 0.0
        b = wire_mode_bytes(self.cfg, seq, d_r, wire_mode)
        if new_tokens > 1:
            b += self.stage0_cache_bytes(seq, split)
        return b

    def stage0_cache_bytes(self, seq: int, split: int) -> float:
        # KV bytes per edge layer: 2 (K and V) * kv_heads * head_dim
        cfg = self.cfg
        per_layer = 2 * seq * cfg.num_kv_heads * cfg.resolved_head_dim * \
            act_bytes(cfg)
        return float(per_layer * split)


# ---------------------------------------------------------------------------
# real numerics: the per-split partitioned models
# ---------------------------------------------------------------------------


class SplitRunner:
    """One partitioned model: jitted edge half, cloud half, full reference."""

    def __init__(self, cfg, *, seed: int = 0, wire_mode: str = "int8"):
        import jax
        import jax.numpy as jnp

        from repro.core.quantization import dequantize, quantize
        from repro.models import model as M
        from repro.models import transformer as tfm
        from repro.models.common import embed, rms_norm, unembed
        from repro.models.parallel import LOCAL

        assert cfg.butterfly is not None, "SplitRunner needs a butterfly cfg"
        assert wire_mode in ("raw", "reduced", "int8"), wire_mode
        self.cfg = cfg
        self.wire_mode = wire_mode
        self.built = M.build(cfg)
        self.params, _ = M.init_model(jax.random.key(seed), self.built)
        dt = jnp.dtype(cfg.dtype)
        stages = self.built.stages
        shared = "shared_attn"

        def edge_half(params, toks):
            scale = cfg.arch_type == "dense" and cfg.act == "gelu"
            x = embed(params["embed"], toks, scale=scale)
            x, cache0, _ = tfm.apply_stage(
                list(stages[0]), params["stages"][0], x, cfg=cfg, pctx=LOCAL,
                mode="prefill", stage_cache=None, pos=None,
                shared_params=params.get(shared))
            if wire_mode == "raw":
                return x, jnp.zeros((x.shape[0], x.shape[1], 1), jnp.float32), cache0
            r = x @ params["butterfly"]["w_reduce"]
            if wire_mode == "reduced":
                return r, jnp.zeros((r.shape[0], r.shape[1], 1), jnp.float32), cache0
            codes, scales = quantize(r, cfg.butterfly.wire_bits)
            return codes, scales, cache0

        def cloud_half(params, payload, scales):
            if wire_mode == "raw":
                x = payload
            else:
                r = payload if wire_mode == "reduced" else \
                    dequantize(payload, scales, dt)
                x = r @ params["butterfly"]["w_restore"]
            x, cache1, _ = tfm.apply_stage(
                list(stages[1]), params["stages"][1], x, cfg=cfg, pctx=LOCAL,
                mode="prefill", stage_cache=None, pos=None,
                shared_params=params.get(shared))
            x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x, cfg.logit_softcap)[:, 0], cache1

        self.edge_half = jax.jit(edge_half)
        self.cloud_half = jax.jit(cloud_half)
        self._M = M

    def make_engine(self, *, max_batch: int, max_len: int, seed: int = 0):
        from repro.serving.engine import ServingEngine
        return ServingEngine(self.params, self.built, max_batch=max_batch,
                             max_len=max_len, seed=seed)

    def reference_prefill(self, toks):
        """Single-mesh forward (what the split path must reproduce)."""
        import jax.numpy as jnp
        logits, caches = self._M.forward_prefill(
            self.params, self.built, {"tokens": jnp.asarray(toks)})
        return logits, caches


class SplitModelBank:
    """Lazily built {candidate split -> SplitRunner}, shared base config.

    The paper's server hosts M partitioned models and the selection phase
    picks among them; candidates here are layer boundaries."""

    def __init__(self, base_cfg, d_r: int, *, wire_bits: int = 8,
                 wire_mode: str = "int8", seed: int = 0):
        assert base_cfg.num_layers >= 2, "need >=2 layers to split"
        self.base_cfg = base_cfg
        self.d_r = d_r
        self.wire_bits = wire_bits
        self.wire_mode = wire_mode
        self.seed = seed
        self._runners: Dict[int, SplitRunner] = {}

    @property
    def candidates(self) -> Tuple[int, ...]:
        return tuple(range(1, self.base_cfg.num_layers))

    def runner(self, split: int) -> SplitRunner:
        if split not in self._runners:
            cfg = self.base_cfg.with_butterfly(split, self.d_r,
                                               self.wire_bits)
            self._runners[split] = SplitRunner(cfg, seed=self.seed,
                                               wire_mode=self.wire_mode)
        return self._runners[split]
