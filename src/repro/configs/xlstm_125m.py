"""xlstm-125m [ssm-family] — alternating mLSTM (matrix memory, chunkwise
parallel) and sLSTM (scalar memory, sequential scan) blocks. [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, XLSTMConfig, register


@register("xlstm-125m")
def xlstm_125m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        arch_type="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,                       # xLSTM blocks carry their own up/down proj
        vocab_size=50304,
        act="gelu",
        tie_embeddings=True,
        xlstm=XLSTMConfig(slstm_every=3, chunk_size=64),
        source="arXiv:2405.04517 (xLSTM 125M: 12 blocks, d=768)",
    )
