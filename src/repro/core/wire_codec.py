"""Entropy codec for the butterfly wire: rANS over learned per-channel priors.

The butterfly's absmax quantizer emits int8/int4 codes whose distribution is
far from uniform — especially once the rate term (``rate_bits``) has pushed
the reduce projection toward low-entropy codes.  This module turns that slack
into wire bytes: a vectorized interleaved-rANS coder (one lane per reduced
channel, numpy state vector, one Python step per token row) codes the symbol
tensor against a per-channel categorical prior.  The coder is *exact*: for
any prior with every symbol representable (``quantize_freqs`` guarantees
freq >= 1), encode -> decode round-trips bitwise, even when the prior badly
mismatches the data — a bad prior only costs bytes, never correctness.

Layout of an encoded payload::

    [T: uint32 LE]                         row count (leading dims flattened)
    [d_r x uint64 LE]                      final rANS lane states
    [uint32 LE ...]                        renormalization words

Interleave order: the decoder consumes words (row ascending, lane ascending);
the encoder walks rows in reverse, appends each step's lane-ascending word
chunk, and reverses the chunk list at flush — the classic interleaved-rANS
stream reversal, vectorized across lanes.

Per-row *decode* streaming keeps fixed-rate int8 rows: the ~12-byte state
flush dwarfs a d_r-symbol row, so entropy coding only pays on prefill-sized
payloads (see DESIGN.md section 18).

Everything here is host-side numpy except ``rate_bits`` (pure jnp,
differentiable — the training-loss hook) and ``expected_bits_per_symbol``.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Optional, Tuple

import numpy as np

# rANS parameters: 12-bit quantized probabilities, 64-bit lane state,
# 32-bit renormalization words.  With freq <= PROB_TOTAL the single-word
# renorm per symbol is guaranteed (state stays below 2**63).
SCALE_BITS = 12
PROB_TOTAL = 1 << SCALE_BITS
RANS_L = 1 << 31
_WORD = 0xFFFFFFFF

# Fixed per-payload overhead: uint32 row count + one uint64 state per lane.
HEADER_BYTES = 4
STATE_BYTES = 8

# Deployment-default coded rate for *predicted* sizes (planner scoring and
# timing-only runs, where no codes exist to encode): a trained prior lands
# around 3.5 bits/symbol on the bench workload (see the `wire` scenario in
# BENCH_runtime.json).  Kept as an exact rational so predicted byte counts
# are integer-deterministic.  Runs with real numerics charge the actual
# coded size instead.
NOMINAL_BITS_NUM = 7
NOMINAL_BITS_DEN = 2


def predicted_code_bytes(n_symbols: int) -> int:
    """ceil(n * 3.5 bits / 8) — the planner's data-free code-byte estimate."""
    return (n_symbols * NOMINAL_BITS_NUM + 8 * NOMINAL_BITS_DEN - 1) \
        // (8 * NOMINAL_BITS_DEN)


def alphabet_size(bits: int) -> int:
    return 1 << bits


def codes_to_symbols(codes, bits: int) -> np.ndarray:
    """Signed quantizer codes [-qmax-1, qmax] -> symbols [0, 2**bits)."""
    qmax = 2 ** (bits - 1) - 1
    sym = np.asarray(codes, dtype=np.int64) + qmax + 1
    if sym.min(initial=0) < 0 or sym.max(initial=0) >= alphabet_size(bits):
        raise ValueError(f"codes out of range for {bits}-bit alphabet")
    return sym


def symbols_to_codes(symbols, bits: int) -> np.ndarray:
    qmax = 2 ** (bits - 1) - 1
    codes = np.asarray(symbols, dtype=np.int64) - qmax - 1
    dtype = np.int8 if bits <= 8 else np.int16
    return codes.astype(dtype)


def quantize_freqs(probs: np.ndarray) -> np.ndarray:
    """(d_r, K) probabilities -> integer freqs, each >= 1, rows sum to
    PROB_TOTAL.  Deterministic: remainder goes to the largest fractional
    parts, ties broken by channel index."""
    p = np.asarray(probs, dtype=np.float64)
    if p.ndim == 1:
        p = p[None]
    d_r, K = p.shape
    if K > PROB_TOTAL:
        raise ValueError(f"alphabet {K} exceeds PROB_TOTAL {PROB_TOTAL}")
    p = np.maximum(p, 0.0)
    row = p.sum(axis=1, keepdims=True)
    p = np.where(row > 0, p / np.maximum(row, 1e-300), 1.0 / K)
    spread = float(PROB_TOTAL - K)
    scaled = p * spread
    f = np.floor(scaled).astype(np.int64) + 1
    short = PROB_TOTAL - f.sum(axis=1)                    # (d_r,) >= 0
    frac = scaled - np.floor(scaled)
    order = np.argsort(-frac, axis=1, kind="stable")      # deterministic ties
    for c in range(d_r):
        n = int(short[c])
        if n:
            f[c, order[c, :n]] += 1
    assert (f >= 1).all() and (f.sum(axis=1) == PROB_TOTAL).all()
    return f


@dataclasses.dataclass(frozen=True)
class WirePrior:
    """Quantized per-channel categorical prior over the code alphabet."""
    bits: int
    freqs: np.ndarray        # (d_r, K) int64, rows sum to PROB_TOTAL
    cumex: np.ndarray        # (d_r, K) exclusive cumulative freqs

    @property
    def d_r(self) -> int:
        return self.freqs.shape[0]

    @classmethod
    def from_probs(cls, probs: np.ndarray, bits: int) -> "WirePrior":
        f = quantize_freqs(probs)
        if f.shape[1] != alphabet_size(bits):
            raise ValueError(f"prior width {f.shape[1]} != 2**{bits}")
        cumex = np.concatenate(
            [np.zeros((f.shape[0], 1), np.int64), np.cumsum(f, axis=1)[:, :-1]],
            axis=1)
        return cls(bits=bits, freqs=f, cumex=cumex)

    @classmethod
    def from_counts(cls, counts: np.ndarray, bits: int,
                    alpha: float = 0.5) -> "WirePrior":
        """Empirical prior from per-channel symbol histograms (the fused
        quantize+bincount kernel's output), Laplace-smoothed."""
        c = np.asarray(counts, dtype=np.float64)
        return cls.from_probs(c + alpha, bits)

    @classmethod
    def default(cls, d_r: int, bits: int, rho: float = 0.8) -> "WirePrior":
        """Deployment default when no trained prior is shipped: a two-sided
        geometric centered on the zero code (absmax-quantized activations
        concentrate there), identical for every channel."""
        K = alphabet_size(bits)
        center = 1 << (bits - 1)
        k = np.arange(K, dtype=np.float64)
        p = rho ** np.abs(k - center)
        return cls.from_probs(np.tile(p[None], (d_r, 1)), bits)


def payload_overhead_bytes(d_r: int) -> int:
    return HEADER_BYTES + STATE_BYTES * d_r


def encode(codes, prior: WirePrior) -> bytes:
    """codes: (..., d_r) signed quantizer codes -> rANS payload bytes."""
    sym = codes_to_symbols(codes, prior.bits)
    d_r = prior.d_r
    if sym.shape[-1] != d_r:
        raise ValueError(f"codes last dim {sym.shape[-1]} != prior d_r {d_r}")
    s = sym.reshape(-1, d_r)
    T = s.shape[0]
    freqs = prior.freqs.astype(np.uint64)
    cumex = prior.cumex.astype(np.uint64)
    lane = np.arange(d_r)
    x = np.full(d_r, RANS_L, dtype=np.uint64)
    x_max_base = np.uint64((RANS_L >> SCALE_BITS) << 32)
    chunks = []
    for t in range(T - 1, -1, -1):
        st = s[t]
        f = freqs[lane, st]
        mask = x >= x_max_base * f
        if mask.any():
            chunks.append((x[mask] & np.uint64(_WORD)).astype(np.uint32))
            x[mask] >>= np.uint64(32)
        x = ((x // f) << np.uint64(SCALE_BITS)) + (x % f) + cumex[lane, st]
    words = (np.concatenate(chunks[::-1]) if chunks
             else np.zeros(0, np.uint32))
    return (struct.pack("<I", T)
            + x.astype("<u8").tobytes()
            + words.astype("<u4").tobytes())


def decode(data: bytes, prior: WirePrior, shape: Tuple[int, ...]) -> np.ndarray:
    """Inverse of :func:`encode`; ``shape`` is the code tensor shape
    (..., d_r).  Raises ValueError on a truncated/corrupt stream or a
    prior that differs from the encoder's."""
    d_r = prior.d_r
    if shape[-1] != d_r:
        raise ValueError(f"shape last dim {shape[-1]} != prior d_r {d_r}")
    n = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 else 1
    (T,) = struct.unpack_from("<I", data, 0)
    if T != n:
        raise ValueError(f"payload rows {T} != requested shape rows {n}")
    off = HEADER_BYTES
    x = np.frombuffer(data, dtype="<u8", count=d_r, offset=off
                      ).astype(np.uint64).copy()
    off += STATE_BYTES * d_r
    words = np.frombuffer(data, dtype="<u4", offset=off).astype(np.uint32)
    freqs = prior.freqs.astype(np.uint64)
    cumex = prior.cumex          # int64, for the searchsorted
    cumex_u = cumex.astype(np.uint64)
    lane = np.arange(d_r)
    out = np.empty((T, d_r), dtype=np.int64)
    pos = 0
    mask_slot = np.uint64(PROB_TOTAL - 1)
    for t in range(T):
        slot = (x & mask_slot).astype(np.int64)
        sym = np.sum(cumex <= slot[:, None], axis=1) - 1
        out[t] = sym
        f = freqs[lane, sym]
        x = f * (x >> np.uint64(SCALE_BITS)) \
            + slot.astype(np.uint64) - cumex_u[lane, sym]
        need = x < RANS_L
        k = int(need.sum())
        if k:
            if pos + k > words.size:
                raise ValueError("truncated rANS stream")
            x[need] = (x[need] << np.uint64(32)) | words[pos:pos + k]
            pos += k
    if pos != words.size or not (x == RANS_L).all():
        raise ValueError("corrupt rANS stream or mismatched encode/decode prior")
    return symbols_to_codes(out, prior.bits).reshape(shape)


def coded_nbytes(codes, prior: Optional[WirePrior] = None) -> int:
    """Actual payload size for a code tensor (runs the real encoder)."""
    arr = np.asarray(codes)
    if prior is None:
        prior = WirePrior.default(arr.shape[-1], 8)
    return len(encode(arr, prior))


def channel_counts(codes, bits: int) -> np.ndarray:
    """(..., d_r) codes -> (d_r, 2**bits) per-channel symbol histogram.
    Host-side oracle for the fused kernel's bincount output."""
    sym = codes_to_symbols(codes, bits).reshape(-1, codes.shape[-1])
    K = alphabet_size(bits)
    d_r = sym.shape[1]
    counts = np.zeros((d_r, K), dtype=np.int64)
    for c in range(d_r):
        counts[c] = np.bincount(sym[:, c], minlength=K)
    return counts


def estimate_coded_bytes(counts, prior: WirePrior) -> int:
    """Predicted payload size from per-channel symbol counts (the fused
    kernel's output) under ``prior`` — cross-entropy ideal length plus the
    fixed rANS overhead.  Tracks the true encoder closely (rANS is within a
    fraction of a percent of the ideal)."""
    c = np.asarray(counts, dtype=np.float64)
    bits_per = SCALE_BITS - np.log2(prior.freqs.astype(np.float64))
    total_bits = float((c * bits_per).sum())
    return int(np.ceil(total_bits / 8.0)) + payload_overhead_bytes(prior.d_r)


def expected_bits_per_symbol(counts, prior: WirePrior) -> float:
    """Mean cross-entropy code length (bits/symbol) of ``counts`` under
    ``prior`` — the quantity the planner's entropy branch approximates."""
    c = np.asarray(counts, dtype=np.float64)
    n = c.sum()
    if n <= 0:
        return 0.0
    bits_per = SCALE_BITS - np.log2(prior.freqs.astype(np.float64))
    return float((c * bits_per).sum() / n)


# ---------------------------------------------------------------------------
# differentiable rate term (training hook)
# ---------------------------------------------------------------------------


def rate_bits(r, bits: int = 8, prior_logits=None):
    """Expected code length (bits/symbol) of the butterfly's reduced
    activations ``r`` (..., d_r) under a per-channel categorical prior —
    differentiable in both ``r`` and ``prior_logits``.

    Mirrors the quantizer's scaling (per-row absmax -> continuous symbol
    position), then linearly interpolates the prior pmf between the two
    neighbouring symbols, so gradients flow into the reduce projection
    (sharper, lower-entropy code distributions) and into the prior.  With
    ``prior_logits=None`` a fixed two-sided geometric prior is used, which
    penalizes code magnitude — the BottleNet-style rate pressure.
    """
    import jax.numpy as jnp

    K = alphabet_size(bits)
    qmax = 2 ** (bits - 1) - 1
    d_r = r.shape[-1]
    absmax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    s = jnp.clip(r / scale + (qmax + 1), 0.0, K - 1.0)     # continuous symbol
    if prior_logits is None:
        center = 1 << (bits - 1)
        k = jnp.arange(K, dtype=jnp.float32)
        logp = jnp.abs(k - center) * jnp.log(0.8)
        logp = logp - jnp.log(jnp.sum(jnp.exp(logp)))
        logp = jnp.tile(logp[None], (d_r, 1))
    else:
        import jax
        logp = jax.nn.log_softmax(prior_logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)                                      # (d_r, K)
    lo = jnp.clip(jnp.floor(s), 0, K - 2).astype(jnp.int32)
    frac = s - lo.astype(s.dtype)
    flat = lo.reshape(-1, d_r)
    ch = jnp.arange(d_r)[None, :]
    p_lo = p[ch, flat].reshape(lo.shape)
    p_hi = p[ch, flat + 1].reshape(lo.shape)
    p_s = p_lo * (1.0 - frac) + p_hi * frac
    return jnp.mean(-jnp.log2(p_s + 1e-12))


# ---------------------------------------------------------------------------
# progressive bitplane schedule
# ---------------------------------------------------------------------------

# High-order bitplanes shipped in the coarse chunk (out of ``bits`` planes).
COARSE_BITS = 4


def coarse_codes(codes, coarse_bits: int = COARSE_BITS, bits: int = 8):
    """Keep the top ``coarse_bits`` bitplanes of each signed code (the chunk
    the cloud prefills on before refinement lands).  Arithmetic shift keeps
    the sign plane; refinement restores the exact code."""
    shift = bits - coarse_bits
    arr = np.asarray(codes)
    return ((arr.astype(np.int64) >> shift) << shift).astype(arr.dtype)


def split_coarse_refine(code_bytes: int, scale_bytes: int,
                        coarse_bits: int = COARSE_BITS,
                        bits: int = 8) -> Tuple[int, int]:
    """Split a coded payload of ``code_bytes`` (+ ``scale_bytes`` of raw
    scales) into (coarse, refine) transfer sizes.  The coarse chunk carries
    the top bitplanes *and* the scales (the cloud can't dequantize without
    them); refinement carries the remaining planes plus a second stream
    header.  coarse + refine >= code_bytes + scale_bytes, never less — the
    split costs a header, it doesn't invent compression."""
    coarse_code = (code_bytes * coarse_bits + bits - 1) // bits
    coarse = coarse_code + scale_bytes
    refine = (code_bytes - coarse_code) + HEADER_BYTES
    return coarse, refine
