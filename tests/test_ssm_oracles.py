"""Chunked scan implementations vs naive sequential oracles: the Mamba2 SSD
chunked form and the chunkwise mLSTM must match step-by-step recurrences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib


def _mamba_cfg(chunk):
    cfg = get_config("zamba2-7b").reduced()
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                            chunk_size=chunk))


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba_chunked_equals_sequential(chunk):
    """Full-seq SSD output == running decode steps one token at a time."""
    cfg = _mamba_cfg(chunk)
    B, S = 2, 16
    params, _ = ssm_lib.init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5

    full, state_full = ssm_lib.mamba_fullseq(params, x, cfg=cfg,
                                             return_state=True)
    state = ssm_lib.init_ssm_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, state = ssm_lib.mamba_decode(params, x[:, t:t+1], state, cfg=cfg)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_full["ssm"]),
                               np.asarray(state["ssm"]), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8])
def test_mlstm_chunked_equals_sequential(chunk):
    cfg = get_config("xlstm-125m").reduced()
    cfg = dataclasses.replace(cfg, xlstm=dataclasses.replace(cfg.xlstm,
                                                             chunk_size=chunk))
    B, S = 2, 16
    params, _ = xlstm_lib.init_mlstm(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5

    full, state_full = xlstm_lib.mlstm_fullseq(params, x, cfg=cfg,
                                               return_state=True)
    state = xlstm_lib.init_mlstm_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, state = xlstm_lib.mlstm_decode(params, x[:, t:t+1], state, cfg=cfg)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(state_full["C"]),
                               np.asarray(state["C"]), rtol=3e-4, atol=3e-4)


def test_slstm_fullseq_equals_decode_steps():
    cfg = get_config("xlstm-125m").reduced()
    B, S = 2, 12
    params, _ = xlstm_lib.init_slstm(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5
    full, state_full = xlstm_lib.slstm_fullseq(params, x, cfg=cfg,
                                               return_state=True)
    state = xlstm_lib.init_slstm_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, state = xlstm_lib.slstm_decode(params, x[:, t:t+1], state, cfg=cfg)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(state_full["c"]),
                               np.asarray(state["c"]), rtol=2e-5, atol=2e-5)


def test_mamba_chunk_size_invariance():
    """Different chunk sizes give the same function (SSD exactness)."""
    B, S = 1, 16
    outs = []
    for chunk in (4, 8, 16):
        cfg = _mamba_cfg(chunk)
        params, _ = ssm_lib.init_mamba(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.5
        o, _ = ssm_lib.mamba_fullseq(params, x, cfg=cfg)
        outs.append(np.asarray(o))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)
