"""Analytic FLOPs/bytes accounting used by the planner's profiling phase and
by the roofline MODEL_FLOPS (useful-compute) denominator.

Conventions: multiply-add = 2 FLOPs; forward pass only (the planner splits
inference).  MODEL_FLOPS for LM training steps uses the standard 6*N*D
(N params, D tokens) with N_active for MoE.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.configs.resnet50 import ResNetConfig

# ---------------------------------------------------------------------------
# transformer per-layer accounting
# ---------------------------------------------------------------------------


def attn_layer_flops(cfg: ModelConfig, seq: int, window: Optional[int] = None,
                     kv_len: Optional[int] = None) -> float:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    proj = 2 * seq * d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    kv = kv_len if kv_len is not None else seq
    eff = min(kv, window) if window else kv
    attn = 2 * seq * eff * cfg.num_heads * hd * 2      # scores + values
    return proj + attn


def mlp_flops(d: int, ff: int, seq: int) -> float:
    return 2 * seq * d * ff * 3


def moe_layer_flops(cfg: ModelConfig, seq: int) -> float:
    m = cfg.moe
    routed = mlp_flops(cfg.d_model, m.d_ff_expert, seq) * m.top_k
    shared = mlp_flops(cfg.d_model, m.shared_expert_ff, seq) if m.shared_expert_ff else 0
    router = 2 * seq * cfg.d_model * m.num_experts
    return routed + shared + router


def mamba_layer_flops(cfg: ModelConfig, seq: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.num_heads * s.head_dim
    proj = 2 * seq * d * (2 * d_inner + 2 * s.state_dim + s.num_heads)
    proj += 2 * seq * d_inner * d
    L = min(s.chunk_size, seq)
    ssd = 2 * seq * L * s.state_dim * 2 + 2 * seq * L * s.head_dim * s.num_heads
    state = 2 * seq * s.num_heads * s.head_dim * s.state_dim * 2
    return proj + ssd + state


def xlstm_layer_flops(cfg: ModelConfig, seq: int, kind: str) -> float:
    d = cfg.d_model
    if kind == "mlstm":
        d_inner = 2 * d
        proj = 2 * seq * d * d_inner * 3 + 2 * seq * d_inner * d_inner * 3 + \
            2 * seq * d_inner * d
        L = min(cfg.xlstm.chunk_size, seq)
        mix = 2 * seq * L * d_inner * 2
        return proj + mix
    # slstm: 4 gate projections + per-head recurrent + small ffn
    H = cfg.num_heads
    Pd = d // H
    rec = 2 * seq * 4 * H * Pd * Pd
    ff = int(d * 8 / 3) // 64 * 64
    return 2 * seq * d * 4 * d + rec + 2 * seq * d * ff * 2


def layer_flops(cfg: ModelConfig, layer_idx: int, seq: int,
                long_mode: bool = False, kv_len: Optional[int] = None) -> float:
    from repro.models.transformer import build_layer_defs
    ldef = build_layer_defs(cfg, long_mode)[layer_idx]
    if ldef.mixer == "attn":
        f = attn_layer_flops(cfg, seq, ldef.window, kv_len)
        if ldef.cross:
            f += attn_layer_flops(cfg, seq, None, cfg.encoder_frames)
        if ldef.ffn == "mlp":
            f += mlp_flops(cfg.d_model, cfg.d_ff, seq)
        elif ldef.ffn == "moe":
            f += moe_layer_flops(cfg, seq)
        return f
    if ldef.mixer == "mamba":
        return mamba_layer_flops(cfg, seq)
    return xlstm_layer_flops(cfg, seq, ldef.mixer)


def stack_flops(cfg: ModelConfig, seq: int, lo: int = 0, hi: Optional[int] = None,
                long_mode: bool = False, kv_len: Optional[int] = None) -> float:
    hi = cfg.num_layers if hi is None else hi
    return sum(layer_flops(cfg, i, seq, long_mode, kv_len) for i in range(lo, hi))


def embed_flops(cfg: ModelConfig, seq: int) -> float:
    return 2 * seq * cfg.d_model * cfg.vocab_size      # unembed matmul


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig, active_only: bool = False) -> float:
    from repro.models.transformer import build_layer_defs
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    shared_attn_counted = False
    for ldef in build_layer_defs(cfg):
        if ldef.mixer == "attn":
            if not (ldef.shared and shared_attn_counted):
                total += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
                if ldef.ffn == "mlp" or ldef.shared:
                    total += 3 * d * cfg.d_ff
                if ldef.shared:
                    shared_attn_counted = True
            if ldef.ffn == "moe":
                m = cfg.moe
                n_exp = m.top_k if active_only else m.num_experts
                total += n_exp * 3 * d * m.d_ff_expert
                total += d * m.num_experts
                if m.shared_expert_ff:
                    total += 3 * d * m.shared_expert_ff
            if ldef.cross:
                total += d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        elif ldef.mixer == "mamba":
            s = cfg.ssm
            din = s.num_heads * s.head_dim
            total += d * (2 * din + 2 * s.state_dim + s.num_heads) + din * d
        elif ldef.mixer == "mlstm":
            din = 2 * d
            total += d * din * 2 + din * din * 3 + din * d
        elif ldef.mixer == "slstm":
            H, Pd = cfg.num_heads, d // cfg.num_heads
            ff = int(d * 8 / 3) // 64 * 64
            total += d * 4 * d + 4 * H * Pd * Pd + 2 * d * ff
    if cfg.is_encdec:
        per_enc = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2) + 3 * d * cfg.d_ff
        total += cfg.encoder_layers * per_enc
    return float(total)


def model_flops_train(cfg: ModelConfig, tokens: int) -> float:
    """The 6*N*D convention (N_active for MoE)."""
    return 6.0 * param_count(cfg, active_only=True) * tokens


def model_flops_decode(cfg: ModelConfig, batch: int) -> float:
    """2*N_active per token forward."""
    return 2.0 * param_count(cfg, active_only=True) * batch


# ---------------------------------------------------------------------------
# per-token split-decode accounting (the streamed decode transport)
# ---------------------------------------------------------------------------

# sampled token ids travel the downlink as int32
TOKEN_BYTES = 4.0


def layer_param_count(cfg: ModelConfig, active_only: bool = True) -> float:
    """Params in the layer stack only (embedding/head tables excluded)."""
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return param_count(cfg, active_only) - emb


def _act_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def model_parallel_share(cost, mp: int = 1):
    """Per-device share of a ``(flops, bytes)`` pair when the stage is
    sharded over a model axis of degree ``mp``: attention heads, d_ff
    columns and experts divide, so FLOPs and weight-streaming bytes both
    scale 1/mp (Megatron column->row sharding).  Activation replication and
    the per-layer psum are not charged — ideal scaling, matching the
    planner's bytes-proxy granularity.  ``mp <= 1`` is the identity, so
    un-sharded callers keep their exact historical estimates."""
    if mp <= 1:
        return cost
    f, b = cost
    return f / mp, b / mp


def full_decode_step_cost(cfg: ModelConfig, batch: int = 1):
    """(flops, weight_bytes) for one full-model decode step (weight-bound:
    every step streams the whole parameter set) — the cost of a cloud-side
    cache-handoff decode turn, used by both the runtime CostModel and the
    planner so the selection phase scores what the simulator charges."""
    return model_flops_decode(cfg, batch), param_count(cfg) * _act_bytes(cfg)


def edge_decode_step_cost(cfg: ModelConfig, split: int, d_r: int):
    """(flops, weight_bytes) per generated token for the edge's streamed
    half: embed lookup + layers [0, split) + the reduction unit.  Decode is
    weight-bound, so bytes stream the edge layers' parameter share."""
    ab = _act_bytes(cfg)
    lp = layer_param_count(cfg) * split / cfg.num_layers
    flops = 2.0 * lp + 2.0 * cfg.d_model * d_r
    nbytes = lp * ab + cfg.d_model * ab            # one embedding row
    return flops, nbytes


def cloud_decode_step_cost(cfg: ModelConfig, split: int, d_r: int,
                           batch: int = 1):
    """(flops, weight_bytes) per decode turn for the cloud's streamed half:
    restoration unit + layers [split, N) + the unembed matmul."""
    ab = _act_bytes(cfg)
    lp = layer_param_count(cfg) * (cfg.num_layers - split) / cfg.num_layers
    flops = batch * (2.0 * lp + 2.0 * d_r * cfg.d_model + embed_flops(cfg, 1))
    nbytes = lp * ab + cfg.vocab_size * cfg.d_model * ab
    return flops, nbytes


def kv_cache_bytes(cfg: ModelConfig, seq: int, layers: int) -> float:
    """KV-cache bytes for ``layers`` attention layers over a ``seq``-token
    prompt: K and V, ``num_kv_heads`` heads of ``head_dim`` each.  This is
    what the cache-handoff decode transport ships up the wire per edge
    layer (and what the selection phase charges it per split)."""
    per_layer = 2 * seq * cfg.num_kv_heads * cfg.resolved_head_dim * \
        _act_bytes(cfg)
    return float(per_layer * layers)


# ---------------------------------------------------------------------------
# resnet accounting (paper's arch)
# ---------------------------------------------------------------------------


def resnet_block_flops(cfg: ResNetConfig, block: int) -> float:
    """Forward FLOPs of residual block ``block`` (1-based)."""
    chans = cfg.block_channels()
    spatial = cfg.block_spatial()
    cout = chans[block - 1]
    sp = spatial[block - 1]
    cin = cfg.stem_channels if block == 1 else chans[block - 2]
    mid = cout // 4
    f = 2 * sp * sp * (cin * mid + 9 * mid * mid + mid * cout)
    if cin != cout:
        f += 2 * sp * sp * cin * cout
    return float(f)


def resnet_stem_flops(cfg: ResNetConfig) -> float:
    sp = cfg.image_size // 2
    return float(2 * sp * sp * 49 * 3 * cfg.stem_channels)


def resnet_split_flops(cfg: ResNetConfig, split: int, d_r: int):
    """(edge_flops, cloud_flops, wire_bytes) for a butterfly after ``split``."""
    chans = cfg.block_channels()
    spatial = cfg.block_spatial()
    edge = resnet_stem_flops(cfg) + sum(resnet_block_flops(cfg, b)
                                        for b in range(1, split + 1))
    edge += 2 * spatial[split - 1] ** 2 * chans[split - 1] * d_r   # reduction
    cloud = 2 * spatial[split - 1] ** 2 * d_r * chans[split - 1]   # restoration
    cloud += sum(resnet_block_flops(cfg, b)
                 for b in range(split + 1, cfg.num_blocks + 1))
    cloud += 2 * chans[-1] * cfg.num_classes
    wire = cfg.feature_bytes(split, bits=8, channels=d_r)
    return edge, cloud, wire
