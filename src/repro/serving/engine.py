"""Batched serving engine: continuous-batching style prefill/decode with a
slot-based KV/state cache pool.

Real-engine behaviours kept: per-request positions (ragged decode), slot
reuse on completion, greedy or temperature sampling, max-token and EOS
stopping.  The decode hot path is one jitted step per batch: sampling
(greedy argmax + temperature categorical) runs *inside* the jitted graph,
so ``step()`` costs a single host sync for the whole slot pool instead of a
per-slot ``device_get`` + Python argmax; per-step logits snapshots are
opt-in (``record_logits``).  Slot admission writes the cache pool through
one jitted donated update instead of an eager per-leaf dispatch.

The engine's forward functions are pluggable: the split runtime's
``SplitModelBank`` supplies jitted prefill/decode closures over the shared
backbone (one compile per split, shared by every engine of that split);
stand-alone engines default to the single-mesh ``models.model`` forwards.
Model-parallel stages thread through the same seam (DESIGN.md section 11):
a bank closure compiled for a ``(model,)`` mesh arrives as a distinct
callable per mesh shape, so the weak-keyed ``_STEP_FNS``/``_STREAM_STEP_FNS``
caches below — keyed on closure identity — can never hand a step compiled
for one mesh to an engine running another; the cache pool itself stays a
global-shape pytree (shard_map assembles/splits the kv-head shards at the
closure boundary).
For the streamed decode transport the engine adds a single-slot entry
(``submit_streamed`` + ``stream_step``): the request holds no cache-pool
slot — its cloud-side stage cache lives with the caller — and each arrived
``(1, d_r)`` row runs through the bank-shared compiled cloud step with
in-graph sampling.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.parallel import LOCAL, ParallelContext


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    record_logits: bool = False         # keep per-step logits (host copies)
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    logits_history: list = dataclasses.field(default_factory=list)
    done: bool = False


@functools.partial(jax.jit, donate_argnums=0)
def _write_slot_jit(pool, new, slot):
    """Copy a single-request cache into batch slot ``slot`` of the pool in
    one compiled dispatch; seq axes of attention caches pad to the pool's
    max_len/window.  The pool buffers are donated so admission updates in
    place where the backend allows."""
    def copy(pool_leaf, new_leaf):
        pad = [(0, 0)] * new_leaf.ndim
        changed = False
        for ax in range(2, new_leaf.ndim):
            if new_leaf.shape[ax] < pool_leaf.shape[ax]:
                pad[ax] = (0, pool_leaf.shape[ax] - new_leaf.shape[ax])
                changed = True
        if changed:
            new_leaf = jnp.pad(new_leaf, pad)
        start = (0, slot) + (0,) * (new_leaf.ndim - 2)
        return jax.lax.dynamic_update_slice(
            pool_leaf, new_leaf.astype(pool_leaf.dtype), start)

    return jax.tree.map(copy, pool, new)


# decode_fn -> jitted (decode + in-graph sampling) step, shared by every
# engine using the same decode closure (e.g. all engines of one bank split)
_STEP_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_STREAM_STEP_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _sample_ingraph(row, key, temps):
    """Greedy argmax + temperature categorical, inside the jitted graph."""
    greedy = jnp.argmax(row, axis=-1).astype(jnp.int32)
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, row.shape[0])
    safe_t = jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, row / safe_t)
    toks = jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)
    return toks, key


def _sampled_step(decode_fn):
    try:
        return _STEP_FNS[decode_fn]
    except KeyError:
        pass

    # the closure must NOT strongly reference decode_fn: the cached value
    # would then keep its own weak key alive and the entry would be
    # immortal, pinning engines/banks (params + cache pools) forever.  The
    # caller holds decode_fn for the engine's lifetime, so the deref only
    # fails after every user of this entry is already gone.
    ref = weakref.ref(decode_fn)

    def step(params, tokens, caches, pos, key, temps):
        logits, caches = ref()(params, tokens, caches, pos)
        row = logits[:, 0].astype(jnp.float32)             # (B, V)
        toks, key = _sample_ingraph(row, key, temps)
        return toks, row, caches, key

    jitted = jax.jit(step)
    _STEP_FNS[decode_fn] = jitted
    return jitted


def _sampled_stream_step(stream_fn):
    """stream_fn -> jitted (cloud half of one streamed row + in-graph
    sampling), shared by every engine wired to the same cloud-step closure
    (all engines of one bank split).  Same weakref discipline as
    :func:`_sampled_step`."""
    try:
        return _STREAM_STEP_FNS[stream_fn]
    except KeyError:
        pass
    ref = weakref.ref(stream_fn)

    def step(params, payload, scales, cache, pos, key, temps):
        logits, cache = ref()(params, payload, scales, cache, pos)
        row = logits[:, 0].astype(jnp.float32)             # (B, V)
        toks, key = _sample_ingraph(row, key, temps)
        return toks, row, cache, key

    jitted = jax.jit(step)
    _STREAM_STEP_FNS[stream_fn] = jitted
    return jitted


class ServingEngine:
    def __init__(self, params, built: M.BuiltModel, *, max_batch: int = 8,
                 max_len: int = 512, pctx: ParallelContext = LOCAL,
                 seed: int = 0, stages=None,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 stream_fn: Optional[Callable] = None,
                 profiler=None, profile_key: tuple = ()):
        self.params = params
        self.built = built
        self.cfg = built.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.pctx = pctx
        dt = jnp.dtype(self.cfg.dtype)
        stage_segs = stages if stages is not None else \
            [list(segs) for segs in built.stages]
        self.cache = [tfm.init_stage_cache(list(segs), self.cfg, max_batch,
                                           max_len, dt)
                      for segs in stage_segs]
        self.positions = np.zeros((max_batch,), np.int32)   # next write pos
        self.active: List[Optional[Request]] = [None] * max_batch
        self.key = jax.random.key(seed)
        self._prefill = prefill_fn or self._default_prefill
        # hold strong refs to the decode/stream closures: the step caches
        # are weak-keyed, so each shared jitted step lives exactly as long
        # as its closure
        self._decode = decode_fn or self._decode_fn
        self._step = _sampled_step(self._decode)
        self._stream = stream_fn
        self._stream_step = _sampled_stream_step(stream_fn) \
            if stream_fn is not None else None
        self._last = np.zeros((max_batch, 1), np.int32)     # last token/slot
        self._temps = np.zeros((max_batch,), np.float32)
        self._uid = 0
        self.decode_steps = 0
        # opt-in wall-clock attribution of the fused sampling steps
        # (metrics.JitProfiler); profile_key distinguishes engines sharing
        # the module-level step caches (e.g. the bank's (split, mp))
        self._profiler = profiler
        self._profile_key = tuple(profile_key)

    # ------------------------------------------------------------------ api
    def submit(self, prompt, max_new_tokens: int = 32, temperature: float = 0.0,
               eos_id: Optional[int] = None,
               record_logits: bool = False) -> Request:
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, record_logits=record_logits)
        self._uid += 1
        slot = self._free_slot()
        self._prefill_into(slot, req)
        return req

    def submit_prefilled(self, prompt_len: int, caches, last_logits,
                         max_new_tokens: int = 32, temperature: float = 0.0,
                         eos_id: Optional[int] = None,
                         record_logits: bool = False) -> Request:
        """Admit a request whose prefill ran elsewhere (the split runtime's
        edge/cloud halves): inject its per-stage caches into a free slot and
        sample the first token from the externally computed last-position
        logits.  ``caches`` must match the engine's stage-cache pytree with
        batch dim 1; seq dims shorter than ``max_len`` are padded."""
        assert prompt_len < self.max_len, "prompt exceeds cache"
        req = Request(self._uid, np.zeros((prompt_len,), np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, record_logits=record_logits)
        self._uid += 1
        slot = self._free_slot()
        self._write_slot(slot, caches)
        self.positions[slot] = prompt_len
        self.active[slot] = req
        last_logits = jnp.asarray(last_logits)
        if req.record_logits:
            req.logits_history.append(jax.device_get(last_logits))
        self._emit(slot, req, self._sample(last_logits, req))
        return req

    def submit_streamed(self, prompt_len: int, last_logits,
                        max_new_tokens: int = 32, temperature: float = 0.0,
                        eos_id: Optional[int] = None,
                        record_logits: bool = False) -> Request:
        """Admit a streamed-decode request: the edge keeps its half's decode
        cache and streams one reduced row per token, so the request holds NO
        cache-pool slot here — the engine only does sampling and stop
        bookkeeping.  The caller owns the cloud-side stage cache and applies
        each arrived row via :meth:`stream_step`."""
        assert prompt_len < self.max_len, "prompt exceeds cache"
        req = Request(self._uid, np.zeros((prompt_len,), np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id, record_logits=record_logits)
        self._uid += 1
        last_logits = jnp.asarray(last_logits)
        if req.record_logits:
            req.logits_history.append(jax.device_get(last_logits))
        tok = self._sample(last_logits, req)
        req.generated.append(tok)
        if (req.eos_id is not None and tok == req.eos_id) or \
                req.max_new_tokens <= 1:
            req.done = True
        return req

    def stream_step(self, req: Request, cache, payload, scales, pos: int):
        """Single-slot streamed decode: apply one externally-computed edge
        row to ``cache`` (the request's cloud-side stage cache) through the
        shared compiled cloud step (one dispatch: restore + layers [split, N)
        + sampling) and return ``(token, new_cache)``."""
        assert self._stream_step is not None, "engine built without stream_fn"
        toks, row, cache, self.key = self._dispatch(
            "engine_stream_step", self._stream_step,
            self.params, jnp.asarray(payload), jnp.asarray(scales), cache,
            jnp.asarray([pos], jnp.int32), self.key,
            jnp.asarray([req.temperature], jnp.float32))
        tok = int(jax.device_get(toks)[0])
        if req.record_logits:
            req.logits_history.append(np.asarray(jax.device_get(row))[0])
        req.generated.append(tok)
        self.decode_steps += 1
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.generated) >= req.max_new_tokens:
            req.done = True
        return tok, cache

    @property
    def num_active(self) -> int:
        return sum(1 for r in self.active if r is not None)

    def run(self, requests_done: Optional[Callable[[], bool]] = None,
            max_steps: int = 10_000):
        """Decode until all slots drain, ``max_steps`` elapse, or the
        ``requests_done`` predicate (checked between steps) fires."""
        steps = 0
        while any(r is not None for r in self.active) and steps < max_steps:
            if requests_done is not None and requests_done():
                break
            self.step()
            steps += 1

    # ------------------------------------------------------------- internals
    def _free_slot(self) -> int:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        raise RuntimeError("engine full; drain before submitting")

    def _default_prefill(self, params, toks):
        batch = {"tokens": jnp.asarray(toks)}
        return M.forward_prefill(params, self.built, batch, self.pctx)

    def _prefill_into(self, slot: int, req: Request):
        S = len(req.prompt)
        assert S < self.max_len, "prompt exceeds cache"
        logits, caches = self._prefill(self.params, req.prompt[None])
        self._write_slot(slot, caches)
        self.positions[slot] = S
        self.active[slot] = req
        if req.record_logits:
            req.logits_history.append(jax.device_get(logits[0, -1]))
        self._emit(slot, req, self._sample(logits[0, -1], req))

    def _emit(self, slot: int, req: Request, tok: int):
        """Record a sampled first token and retire single-token requests."""
        req.generated.append(tok)
        self._last[slot, 0] = tok
        self._temps[slot] = req.temperature
        if (req.eos_id is not None and tok == req.eos_id) or \
                req.max_new_tokens <= 1:
            req.done = True
            self.active[slot] = None

    def _dispatch(self, kind: str, fn, *args):
        """Run a fused step, optionally through the wall-clock profiler."""
        if self._profiler is None:
            return fn(*args)
        return self._profiler.timed((kind,) + self._profile_key, fn, *args)

    def _write_slot(self, slot: int, req_cache):
        self.cache = _write_slot_jit(self.cache, req_cache, jnp.int32(slot))

    def _decode_fn(self, params, tokens, caches, pos):
        return M.forward_decode(params, self.built, tokens, caches, pos,
                                self.pctx)

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / req.temperature))

    def step(self):
        """One batched decode step over all active slots: a single jitted
        dispatch (forward + sampling) and a single host sync for the
        sampled tokens."""
        if not any(r is not None for r in self.active):
            return
        # .copy() is load-bearing: on the CPU backend jnp.asarray can alias
        # the numpy buffer zero-copy, and the in-place `positions[i] += 1`
        # below would race with the still-dispatching decode (observed as a
        # rare wrong-slot cache write under load)
        pos = jnp.asarray(self.positions.copy())
        toks, logits, self.cache, self.key = self._dispatch(
            "engine_step", self._step,
            self.params, jnp.asarray(self._last.copy()), self.cache, pos,
            self.key, jnp.asarray(self._temps.copy()))
        toks_host = np.asarray(jax.device_get(toks))       # the one host sync
        logits_host = None
        self.decode_steps += 1
        for i, r in enumerate(self.active):
            if r is None:
                continue
            self.positions[i] += 1
            tok = int(toks_host[i])
            self._last[i, 0] = tok
            if r.record_logits:
                if logits_host is None:     # already computed; copy-only
                    logits_host = np.asarray(jax.device_get(logits))
                r.logits_history.append(logits_host[i])
            r.generated.append(tok)
            if (r.eos_id is not None and tok == r.eos_id) or \
                    len(r.generated) >= r.max_new_tokens or \
                    self.positions[i] >= self.max_len - 1:
                r.done = True
                self.active[i] = None
