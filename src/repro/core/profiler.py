"""Latency/energy profiling for the partitioning algorithm.

The paper measures wall-clock on a Jetson TX2 + GTX 1080 Ti (INA226 power
sensor).  This container has no such hardware, so profiles come from a
roofline cost model: t = max(flops / peak_flops, bytes / mem_bw), plus the
wireless (or interconnect) uplink term.  The paper's own published per-split
profile (Table IV) is also encoded so Algorithm 1's selection phase can be
validated against Table V exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.wireless import NETWORKS, WirelessNetwork


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops: float                 # peak FLOP/s at compute dtype
    mem_bw: float                # bytes/s
    compute_power_w: float = 0.0  # average power while computing (edge energy)

    def latency_s(self, flops: float, nbytes: float) -> float:
        return max(flops / self.flops, nbytes / self.mem_bw)

    def scaled(self, factor: float, name: Optional[str] = None) -> "HardwareProfile":
        """A platform ``factor``x this one (compute and bandwidth alike) —
        e.g. the cloud slice a single request sees on a shared server."""
        return HardwareProfile(name or f"{self.name}_x{factor:g}",
                               self.flops * factor, self.mem_bw * factor,
                               self.compute_power_w)


# paper platforms (Tables I/II): TX2 ~1.33 TFLOP/s FP16, 59.7 GB/s;
# GTX 1080 Ti ~ 30x the TX2 per the paper's own characterization.
JETSON_TX2 = HardwareProfile("jetson_tx2", 1.33e12, 59.7e9, compute_power_w=7.5)
GTX_1080TI = HardwareProfile("gtx_1080ti", 1.33e12 * 30, 484e9, compute_power_w=250.0)
# TPU v5e target (assignment constants)
TPU_V5E = HardwareProfile("tpu_v5e", 197e12, 819e9, compute_power_w=170.0)
# phone-class NPU (mid-range smartphone DSP/NPU slice: ~1/4 of a TX2 at a
# fraction of the power budget) — the weak end of a heterogeneous fleet
PHONE_NPU = HardwareProfile("phone_npu", 0.35e12, 25.6e9, compute_power_w=2.5)

# edge-device classes a multi-cell topology's fleets draw from (CellSpec
# names a class per cell; runtime_sim's --topology grammar uses the keys)
DEVICE_CLASSES: Dict[str, HardwareProfile] = {
    "phone": PHONE_NPU,
    "jetson": JETSON_TX2,
}


def get_device_class(name) -> HardwareProfile:
    """Resolve a device-class name (or pass a HardwareProfile through)."""
    if isinstance(name, HardwareProfile):
        return name
    try:
        return DEVICE_CLASSES[name]
    except KeyError:
        raise KeyError(f"unknown device class {name!r}; known: "
                       f"{sorted(DEVICE_CLASSES)}") from None


@dataclass(frozen=True)
class SplitProfile:
    """Per-candidate-split measurements: the planner's profiling-phase row."""
    split: int                   # partition point id (e.g. residual block)
    d_r: int                     # minimal bottleneck width for the split
    edge_seconds: float          # TM_j
    edge_power_w: float          # PM_j
    cloud_seconds: float         # TC_j
    wire_bytes: int              # F_{P_j} after reduction+quant

    def latency(self, network: WirelessNetwork) -> float:
        return self.edge_seconds + network.uplink_seconds(self.wire_bytes) + \
            self.cloud_seconds

    def mobile_energy_mj(self, network: WirelessNetwork) -> float:
        compute = self.edge_seconds * self.edge_power_w * 1e3
        return compute + network.uplink_energy_mj(self.wire_bytes)


def profile_split(split: int, d_r: int, *, edge_flops: float, edge_bytes: float,
                  cloud_flops: float, cloud_bytes: float, wire_bytes: int,
                  edge: HardwareProfile, cloud: HardwareProfile,
                  edge_load: float = 0.0, cloud_load: float = 0.0) -> SplitProfile:
    """Roofline-model profiling of one candidate split.  ``*_load`` in [0,1)
    derates the platform (the paper's K_mobile / K_cloud congestion knobs)."""
    t_edge = edge.latency_s(edge_flops, edge_bytes) / max(1e-9, 1.0 - edge_load)
    t_cloud = cloud.latency_s(cloud_flops, cloud_bytes) / max(1e-9, 1.0 - cloud_load)
    return SplitProfile(split=split, d_r=d_r, edge_seconds=t_edge,
                        edge_power_w=edge.compute_power_w,
                        cloud_seconds=t_cloud, wire_bytes=wire_bytes)


# ---------------------------------------------------------------------------
# The paper's own measured profile (Table IV + Table V rows), for validating
# the selection phase against published numbers.
# ---------------------------------------------------------------------------

PAPER_TABLE4 = {
    # rb: (offloaded_kb, lat3g_ms, en3g_mj, lat4g_ms, en4g_mj, latwifi_ms, enwifi_mj)
    1: (3.1, 23.7, 21.6, 5.2, 9.8, 2.4, 4.8),
    2: (3.1, 24.7, 22.4, 6.1, 11.6, 3.3, 6.8),
    3: (3.1, 25.6, 23.3, 6.9, 13.2, 4.1, 8.7),
    4: (1.6, 15.0, 13.7, 5.8, 10.9, 4.3, 9.1),
    5: (1.6, 15.9, 14.4, 6.7, 12.7, 5.2, 11.2),
    6: (1.6, 16.8, 15.4, 7.6, 14.3, 6.1, 13.1),
    7: (1.6, 17.7, 16.2, 8.5, 15.9, 7.0, 14.9),
    8: (1.0, 14.3, 13.1, 8.6, 12.6, 7.7, 12.1),
    9: (1.0, 15.4, 13.9, 9.6, 13.1, 8.6, 12.7),
    10: (1.0, 16.2, 14.7, 10.5, 14.3, 9.4, 13.9),
    11: (1.0, 17.1, 15.5, 11.2, 15.2, 10.7, 14.8),
    12: (1.0, 17.9, 16.4, 12.1, 16.3, 11.1, 15.5),
    13: (1.0, 18.8, 17.2, 13.1, 17.0, 12.2, 16.3),
    14: (0.5, 16.1, 14.8, 13.1, 14.4, 12.9, 14.1),
    15: (0.5, 17.1, 15.7, 14.2, 16.8, 13.8, 16.1),
    16: (0.5, 17.9, 16.6, 15.1, 17.2, 14.7, 16.6),
}

PAPER_CLOUD_ONLY = {"3g": (1101.0, 1047.4), "4g": (208.4, 528.3),
                    "wifi": (98.1, 342.1)}   # (latency ms, energy mJ)
PAPER_MOBILE_ONLY = (15.7, 20.5)
PAPER_INPUT_BYTES = 150528                    # 224*224*3


def paper_profiles() -> Dict[str, Dict[int, Dict[str, float]]]:
    """{network: {rb: {latency_ms, energy_mj, wire_bytes}}} from Table IV."""
    out: Dict[str, Dict[int, Dict[str, float]]] = {"3g": {}, "4g": {}, "wifi": {}}
    for rb, (kb, l3, e3, l4, e4, lw, ew) in PAPER_TABLE4.items():
        out["3g"][rb] = {"latency_ms": l3, "energy_mj": e3, "wire_bytes": kb * 1e3}
        out["4g"][rb] = {"latency_ms": l4, "energy_mj": e4, "wire_bytes": kb * 1e3}
        out["wifi"][rb] = {"latency_ms": lw, "energy_mj": ew, "wire_bytes": kb * 1e3}
    return out
