"""Quickstart: build a reduced model with the paper's butterfly unit, train
it end-to-end on the synthetic LM stream, checkpoint, restore and serve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.training import (AdamWConfig, adamw_init, constant_schedule,
                            make_train_step)
from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def main():
    # 1. a reduced qwen3 with the butterfly bottleneck after layer 1
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=128)
    cfg = cfg.with_butterfly(layer=1, d_r=16)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    print(f"model: {cfg.name}, butterfly after layer {cfg.butterfly.layer} "
          f"(d_model {cfg.d_model} -> d_r {cfg.butterfly.d_r}, int8 wire)")

    # 2. train end-to-end THROUGH the quantized bottleneck (paper Sec. II)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(built, AdamWConfig(lr=constant_schedule(3e-3))))
    for i, raw in zip(range(80), lm_batches(cfg.vocab_size, 64, 16)):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 20 == 0 or i == 79:
            print(f"  step {i:3d} loss {float(metrics['loss']):.3f}")

    # 3. checkpoint round-trip
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(f"{d}/ckpt", params, opt, step=80)
        params, _, meta = restore_checkpoint(path, params)
        print("checkpoint restored:", meta)

    # 4. serve a few requests (prefill + batched ragged decode)
    eng = ServingEngine(params, built, max_batch=4, max_len=128)
    reqs = [eng.submit(np.arange(1 + i, 9 + i) % cfg.vocab_size,
                       max_new_tokens=12) for i in range(3)]
    eng.run()
    for r in reqs:
        print(f"  request {r.uid}: generated {r.generated}")


if __name__ == "__main__":
    main()
