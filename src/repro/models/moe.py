"""Mixture-of-Experts layer with sort-based dropped dispatch and explicit
expert parallelism.

Design notes (DESIGN.md section 10):
  * The naive one-hot dispatch tensor (tokens, experts, capacity) is O(T*E*C)
    and OOMs at assigned scales; instead tokens are ranked into per-expert
    capacity slots with an argsort over expert ids (O(T*k log T*k) ints) and
    scattered directly into an (E_local, capacity, d) buffer.
  * Under a mesh, the layer runs inside shard_map: activations are sharded
    over the data axes and replicated over the model axis; each model rank
    owns E/mp experts, computes only its slice, and the partial outputs are
    psum'ed over the model axis.  Expert weights are additionally sharded
    over the data axis on the d_ff dim (FSDP) and all-gathered just-in-time.
  * Router math in f32; load-balance + router-z aux losses returned.
"""
from __future__ import annotations

import functools
import math
import os as _os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import dense_init, glu_act
from repro.models.parallel import ParallelContext

# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


# Multi-pod FSDP (EXPERIMENTS.md section Perf, extension): shard the expert
# dim over BOTH the pod and model axes (e.g. 128 experts / 32 ranks) so that
# 400B-scale MoE optimizer state fits v5e HBM.  Opt-in because it changes
# which mesh the specs target (the dry-run sets it for multi-pod runs).
EXPERTS_OVER_POD = _os.environ.get("REPRO_MOE_EXPERTS_OVER_POD", "0") == "1"


def expert_axes():
    return ("pod", "model") if EXPERTS_OVER_POD else "model"


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    E, F = m.num_experts, m.d_ff_expert
    params = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wg": jax.random.truncated_normal(ks[1], -2, 2, (E, d, F), jnp.float32)
            .astype(dtype) * math.sqrt(1.0 / d),
        "wu": jax.random.truncated_normal(ks[2], -2, 2, (E, d, F), jnp.float32)
            .astype(dtype) * math.sqrt(1.0 / d),
        "wd": jax.random.truncated_normal(ks[3], -2, 2, (E, F, d), jnp.float32)
            .astype(dtype) * math.sqrt(1.0 / F),
    }
    # expert dim -> model axis (+ pod when enabled); d_ff -> data axis (FSDP)
    ff_ax = "data" if F % 16 == 0 else None
    e_ax = expert_axes()
    specs = {
        "router": P(None, None),
        "wg": P(e_ax, None, ff_ax),
        "wu": P(e_ax, None, ff_ax),
        "wd": P(e_ax, ff_ax, None),
    }
    if m.shared_expert_ff:
        from repro.models.common import init_mlp
        params["shared"], specs["shared"] = init_mlp(ks[4], d, m.shared_expert_ff, dtype)
    return params, specs


# ---------------------------------------------------------------------------
# shard-local dispatch/compute/combine
# ---------------------------------------------------------------------------


def _moe_shard(x_flat, router, wg, wu, wd, *, mcfg: MoEConfig, act: str,
               e_offset, capacity: int, model_axis: Optional[str]):
    """x_flat: (T, d) local tokens; wg/wu/wd: this rank's expert slice."""
    T, d = x_flat.shape
    E, k = mcfg.num_experts, mcfg.top_k
    E_local = wg.shape[0]

    # --- routing (f32) ----------------------------------------------------
    logits = x_flat.astype(jnp.float32) @ router                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eids = jax.lax.top_k(probs, k)                          # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- capacity slot assignment (ints only) -----------------------------
    flat_e = eids.reshape(-1)                                     # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    group_start = jnp.searchsorted(se, jnp.arange(E))
    pos_sorted = jnp.arange(T * k) - group_start[se]
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    pos = pos_sorted[inv].reshape(T, k)                           # slot within expert

    local_e = eids - e_offset
    keep = (pos < capacity) & (local_e >= 0) & (local_e < E_local)
    # flattened destination row in the (E_local*capacity, d) buffer
    dst = jnp.where(keep, local_e * capacity + pos, E_local * capacity)

    # --- dispatch: k scatters of (T, d), no (T*k, d) gather ---------------
    buf = jnp.zeros((E_local * capacity, d), x_flat.dtype)
    for j in range(k):
        buf = buf.at[dst[:, j]].set(x_flat, mode="drop")
    buf = buf.reshape(E_local, capacity, d)

    # --- expert ffn --------------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = glu_act(g, act) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_local * capacity, d)

    # --- combine: k gathers weighted by gates ------------------------------
    out = jnp.zeros((T, d), x_flat.dtype)
    for j in range(k):
        vals = jnp.take(out_buf, dst[:, j], axis=0, mode="fill", fill_value=0)
        out = out + gate[:, j, None].astype(x_flat.dtype) * vals

    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)

    # --- aux losses (identical on every model rank) ------------------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    frac = jnp.zeros((E,)).at[eids.reshape(-1)].add(1.0) / (T * k)
    lb_loss = E * jnp.sum(me * frac)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out, lb_loss, z_loss


def _capacity(tokens_local: int, mcfg: MoEConfig) -> int:
    cap = int(math.ceil(tokens_local * mcfg.top_k / mcfg.num_experts
                        * mcfg.capacity_factor))
    return max(cap, 1)


# ---------------------------------------------------------------------------
# decode path: broadcast tokens, never gather weights
# ---------------------------------------------------------------------------
# Perf iteration (EXPERIMENTS.md section Perf, llama4 decode_32k): the train
# path all-gathers each MoE layer's expert weights over the data axis (FSDP)
# — fine when amortized over 65k tokens/rank, catastrophic for 1-token decode
# (GBs of weight movement per step).  For decode we instead all-gather the
# *tokens* (KBs), compute on the resident (E/mp, d, ff/dp) weight shard, and
# psum the (T_global, d) partial outputs over BOTH axes (expert partitioning
# over 'model' + ff partial sums over 'data').
# Confirmed in EXPERIMENTS.md section Perf pair 1 (116-591x fewer collective
# bytes) and correctness-tested against the local oracle, so it is the
# framework default; set REPRO_MOE_DECODE_BROADCAST=0 to reproduce the
# baseline (weight all-gather) dry-runs.
DECODE_BROADCAST = _os.environ.get("REPRO_MOE_DECODE_BROADCAST", "1") == "1"


def _moe_decode_shard(x_all, router, wg, wu, wd, *, mcfg: MoEConfig, act: str,
                      e_offset, capacity: int, model_axis, data_axes):
    """x_all: (T_global, d) identical on every rank; wg/wu/wd: the rank's
    resident (E_local, d, ff_local) shard — no weight gathering."""
    out, lb, zl = _moe_shard(x_all, router, wg, wu, wd, mcfg=mcfg, act=act,
                             e_offset=e_offset, capacity=capacity,
                             model_axis=None)
    out = jax.lax.psum(out, (model_axis, *data_axes))
    return out, lb, zl


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def apply_moe(params, x, *, cfg: ModelConfig, pctx: ParallelContext, act: str):
    """x: (B, S, d) -> (out, aux dict)."""
    mcfg = cfg.moe
    B, S, d = x.shape
    if not pctx.enabled or pctx.manual:
        # manual: the caller is already inside a shard_map body (the split
        # pipeline's model-parallel stages) — no nested shard_map.  This rank
        # holds an E/mp expert slice; tokens are replicated over the model
        # axis, every rank ranks the full token set into the same capacity
        # slots (identical f32 router math), computes only its experts, and
        # _moe_shard psums the partial combine over the model axis.  With
        # mp == 1 (or no mesh) this is exactly the local path, so the
        # replicated pipeline's numerics are untouched.
        mp = pctx.mp_size if pctx.manual else 1
        assert mcfg.num_experts % mp == 0, (mcfg.num_experts, mp)
        e_off = 0
        if mp > 1:
            e_off = jax.lax.axis_index(pctx.model_axis) * \
                (mcfg.num_experts // mp)
        cap = _capacity(B * S, mcfg)
        out, lb, zl = _moe_shard(
            x.reshape(B * S, d), params["router"], params["wg"], params["wu"],
            params["wd"], mcfg=mcfg, act=act, e_offset=e_off, capacity=cap,
            model_axis=pctx.model_axis if mp > 1 else None)
        out = out.reshape(B, S, d)
    else:
        dp, mp = pctx.dp_size, pctx.mp_size
        over_pod = EXPERTS_OVER_POD and pctx.mesh is not None and \
            "pod" in pctx.mesh.axis_names
        n_pods = pctx.mesh.shape["pod"] if over_pod else 1
        ep = n_pods * mp
        assert B % dp == 0 or B < dp, (B, dp)
        assert mcfg.num_experts % ep == 0, (mcfg.num_experts, ep)
        batch_sharded = B % dp == 0 and B >= dp
        T_l = (B // dp if batch_sharded else B) * S
        decode_path = DECODE_BROADCAST and S == 1
        # experts over pod: tokens are pod-sharded but every expert rank must
        # see all candidate tokens -> gather over pod, slice back after psum
        cap = _capacity(B * S if decode_path else T_l * n_pods, mcfg)
        dpx = pctx.batch_spec_axes() if batch_sharded else None
        ff_ax = "data" if mcfg.d_ff_expert % 16 == 0 else None

        def shard_fn(xb, router, wg, wu, wd):
            rank = jax.lax.axis_index(pctx.model_axis)
            if over_pod:
                rank = jax.lax.axis_index("pod") * mp + rank
            e_off = rank * (mcfg.num_experts // ep)
            if decode_path:
                # gather the (tiny) token block instead of the weights;
                # reversed order => row blocks are data_axes[0]-major, which
                # matches the slice-back index below
                x_all = xb.reshape(-1, d)
                if batch_sharded:
                    for ax in reversed(pctx.data_axes):
                        x_all = jax.lax.all_gather(x_all, ax, axis=0,
                                                   tiled=True)
                # psum combines expert partitions (model) + ff partials; the
                # ff shard lives on 'data' only, never on 'pod' (pod ranks
                # hold identical shards, so summing over pod would double)
                psum_data = ("data",) if ff_ax is not None else ()
                out, lb, zl = _moe_decode_shard(
                    x_all, router, wg, wu, wd, mcfg=mcfg, act=act,
                    e_offset=e_off, capacity=cap,
                    model_axis=pctx.model_axis,
                    data_axes=psum_data)
                if batch_sharded:
                    # take back this rank's batch slice
                    idx = jax.lax.axis_index(pctx.data_axes[-1])
                    if len(pctx.data_axes) > 1:
                        outer = jax.lax.axis_index(pctx.data_axes[0])
                        idx = outer * pctx.mesh.shape[pctx.data_axes[-1]] + idx
                    out = jax.lax.dynamic_slice_in_dim(
                        out, idx * (B // dp), B // dp, axis=0)
                lb = jax.lax.pmean(lb, pctx.data_axes)
                zl = jax.lax.pmean(zl, pctx.data_axes)
                return out.reshape(xb.shape), lb, zl
            if ff_ax is not None:
                wg = jax.lax.all_gather(wg, ff_ax, axis=2, tiled=True)
                wu = jax.lax.all_gather(wu, ff_ax, axis=2, tiled=True)
                wd = jax.lax.all_gather(wd, ff_ax, axis=1, tiled=True)
            xf = xb.reshape(-1, d)
            if over_pod:
                xf = jax.lax.all_gather(xf, "pod", axis=0, tiled=True)
            out, lb, zl = _moe_shard(
                xf, router, wg, wu, wd, mcfg=mcfg, act=act,
                e_offset=e_off, capacity=cap,
                model_axis=("pod", pctx.model_axis) if over_pod
                else pctx.model_axis)
            if over_pod:
                pod_idx = jax.lax.axis_index("pod")
                out = jax.lax.dynamic_slice_in_dim(
                    out, pod_idx * (xf.shape[0] // n_pods),
                    xf.shape[0] // n_pods, axis=0)
            # aux losses averaged over data shards for reporting
            lb = jax.lax.pmean(lb, pctx.data_axes)
            zl = jax.lax.pmean(zl, pctx.data_axes)
            return out.reshape(xb.shape), lb, zl

        e_ax = ("pod", "model") if over_pod else "model"
        out, lb, zl = compat.shard_map(
            shard_fn, mesh=pctx.mesh,
            in_specs=(P(dpx, None, None), P(None, None),
                      P(e_ax, None, ff_ax), P(e_ax, None, ff_ax),
                      P(e_ax, ff_ax, None)),
            out_specs=(P(dpx, None, None), P(), P()),
        )(x, params["router"], params["wg"], params["wu"], params["wd"])

    aux = {"load_balance": lb * mcfg.load_balance_coef,
           "router_z": zl * mcfg.router_z_coef}
    if mcfg.shared_expert_ff:
        from repro.models.common import apply_mlp
        out = out + apply_mlp(params["shared"], x, act)
    return out, aux
