"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + allclose, per assignment deliverable c."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("T,d,d_r", [(32, 128, 8), (64, 256, 32), (100, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_butterfly_reduce_quant(T, d, d_r, dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (T, d), dtype)
    w = (jax.random.normal(k2, (d, d_r), jnp.float32) * 0.05).astype(dtype)
    codes, scales = ops.butterfly_reduce_quant(x, w, block_t=32)
    codes_r, scales_r = ref.butterfly_reduce_quant_ref(x, w)
    assert codes.dtype == jnp.int8
    # int8 codes may differ by 1 ULP at rounding boundaries in bf16
    diff = np.abs(np.asarray(codes, np.int32) - np.asarray(codes_r, np.int32))
    assert diff.max() <= (0 if dtype == jnp.float32 else 1)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("T,d,d_r", [(32, 128, 8), (48, 256, 16)])
def test_butterfly_dequant_restore(T, d, d_r):
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    x = jax.random.normal(k1, (T, d), jnp.float32)
    w = jax.random.normal(k2, (d, d_r), jnp.float32) * 0.05
    wr = jax.random.normal(k3, (d_r, d), jnp.float32) * 0.05
    codes, scales = ref.butterfly_reduce_quant_ref(x, w)
    out = ops.butterfly_dequant_restore(codes, scales, wr, block_t=16)
    out_r = ref.butterfly_dequant_restore_ref(codes, scales, wr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,d,d_r", [(32, 128, 8),     # kernel grid path
                                     (4, 128, 16)])    # decode-row fast path
def test_butterfly_restore_norm_vs_ref(T, d, d_r):
    """Fused dequant+restore+norm1 against the oracle AND against the
    unfused composition it replaces (restore, then rms_norm)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.key(5), 4)
    x = jax.random.normal(k1, (T, d), jnp.float32)
    w = jax.random.normal(k2, (d, d_r), jnp.float32) * 0.05
    wr = jax.random.normal(k3, (d_r, d), jnp.float32) * 0.05
    nw = jax.random.normal(k4, (d,), jnp.float32) * 0.1
    codes, scales = ref.butterfly_reduce_quant_ref(x, w)
    xr, h = ops.butterfly_restore_norm(codes, scales, wr, nw, block_t=16)
    xr_r, h_r = ref.butterfly_restore_norm_ref(codes, scales, wr, nw)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xr_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)
    unfused_x = ops.butterfly_dequant_restore(codes, scales, wr, block_t=16)
    unfused_h = ops.rmsnorm_ref(unfused_x, nw)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(unfused_x),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h), np.asarray(unfused_h),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("T,d,d_r", [(32, 128, 8),     # kernel grid path
                                     (100, 128, 16),   # padded grid (count fix)
                                     (4, 128, 16)])    # decode-row fast path
@pytest.mark.parametrize("bits", [8, 4])
def test_butterfly_reduce_quant_bincount(T, d, d_r, bits):
    """Fused quantize+per-channel-bincount: codes/scales bitwise-identical
    to the plain fused quantize, counts bitwise vs the host histogram
    oracle (including the padded-grid correction), eager ref within the
    repo's usual quant tolerance."""
    from repro.core import wire_codec
    k1, k2 = jax.random.split(jax.random.key(9))
    x = jax.random.normal(k1, (T, d), jnp.float32)
    w = jax.random.normal(k2, (d, d_r), jnp.float32) * 0.05
    codes, scales, counts = ops.butterfly_reduce_quant_bincount(
        x, w, bits=bits, block_t=32)
    codes_p, scales_p = ops.butterfly_reduce_quant(x, w, bits=bits,
                                                   block_t=32)
    assert np.array_equal(np.asarray(codes), np.asarray(codes_p))
    assert np.array_equal(np.asarray(scales), np.asarray(scales_p))
    assert np.array_equal(np.asarray(counts),
                          wire_codec.channel_counts(np.asarray(codes), bits))
    assert int(np.asarray(counts).sum()) == T * d_r
    codes_r, scales_r, counts_r = ref.butterfly_reduce_quant_bincount_ref(
        x, w, bits=bits)
    assert np.array_equal(np.asarray(codes), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r),
                               rtol=1e-5, atol=1e-7)
    assert np.array_equal(np.asarray(counts), np.asarray(counts_r))


def test_butterfly_roundtrip_error_bound():
    """|x - deq(quant(x))| <= scale/2 per element (symmetric rounding)."""
    x = jax.random.normal(jax.random.key(2), (64, 128), jnp.float32)
    w = jnp.eye(128)
    codes, scales = ops.butterfly_reduce_quant(x, w, block_t=32)
    back = codes.astype(jnp.float32) * scales
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(scales)) * 0.5 + 1e-6


ATTN_CASES = [
    # B, Sq, Skv, N, K, hd, causal, window
    (2, 128, 128, 4, 2, 64, True, None),
    (2, 128, 128, 4, 2, 64, True, 32),
    (1, 128, 128, 8, 8, 32, False, None),
    (2, 64, 128, 4, 4, 64, True, None),       # continuation (q aligned to end)
    (1, 1, 128, 4, 2, 64, True, None),        # decode-like
]


@pytest.mark.parametrize("B,Sq,Skv,N,K,hd,causal,window", ATTN_CASES)
def test_flash_attention_vs_ref(B, Sq, Skv, N, K, hd, causal, window):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, Sq, N, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, K, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=min(64, Sq), block_k=64)
    o_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 64), dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 64), dtype)
    o = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    o_r = ref.flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_r, np.float32), rtol=tol, atol=tol)


def test_model_attention_uses_kernel_consistently():
    """Model attention with use_kernel=True equals the jnp path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("qwen3-8b").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    a, _ = M.forward_train(params, built, {"tokens": toks}, use_kernel=False)
    b, _ = M.forward_train(params, built, {"tokens": toks}, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,d", [(32, 128), (100, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_vs_ref(T, d, dtype):
    x = jax.random.normal(jax.random.key(7), (T, d), dtype)
    w = (jax.random.normal(jax.random.key(8), (d,), jnp.float32) * 0.1).astype(dtype)
    got = ops.rmsnorm(x, w, block_t=32)
    want = ops.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)
