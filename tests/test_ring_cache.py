"""Property tests for the windowed ring-buffer KV cache (hypothesis):
prefill-then-decode through arbitrary window/length combinations must equal
the full-sequence computation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property suites need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import attention as attn
from repro.models.transformer import to_ring


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 12), st.data())
def test_ring_decode_matches_fullseq(window, extra, data):
    """Decode `extra` tokens after a prefill of `pre` tokens with a ring
    cache of size `window`; last-token attention output must match the
    full-sequence windowed attention."""
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              num_heads=2, num_kv_heads=1, head_dim=16,
                              qk_norm=False)
    pre = data.draw(st.integers(1, 10))
    S = pre + extra
    params, _ = attn.init_attention(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, S, cfg.d_model)) * 0.3

    full, kv = attn.attention_fullseq(params, x, cfg=cfg, window=window)

    # prefill the first `pre` tokens, ring-ify, then decode the rest
    _, kv_pre = attn.attention_fullseq(params, x[:, :pre], cfg=cfg,
                                       window=window)
    cache = to_ring(kv_pre, window)
    if cache["k"].shape[1] < window:      # pad short prefill up to window
        pad = window - cache["k"].shape[1]
        cache = {k: jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                 for k, v in cache.items()}
    out = None
    for t in range(pre, S):
        out, cache = attn.attention_decode(params, x[:, t:t + 1], cache,
                                           jnp.asarray(t, jnp.int32),
                                           cfg=cfg, window=window)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(out[:, 0]),
                               rtol=2e-4, atol=2e-4)


def test_to_ring_is_permutation():
    kv = {"k": jnp.arange(2 * 10 * 1 * 4, dtype=jnp.float32).reshape(2, 10, 1, 4),
          "v": jnp.zeros((2, 10, 1, 4))}
    W = 4
    ring = to_ring(kv, W)
    assert ring["k"].shape[1] == W
    # positions 6..9 land at slots 6%4..9%4 = 2,3,0,1
    tail = np.asarray(kv["k"][:, -W:])
    got = np.asarray(ring["k"])
    for i, p in enumerate(range(10 - W, 10)):
        np.testing.assert_array_equal(got[:, p % W], tail[:, i])
