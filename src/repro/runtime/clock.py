"""Deterministic discrete-event simulation core.

A single virtual clock advances only when events fire; equal-time events run
in submission order (FIFO tie-break), so a simulation with a fixed seed
produces bit-identical traces on every host — the property the runtime tests
and the benchmark's cloud-only/split comparisons rely on.

One-shot events are cancellable: :meth:`EventLoop.schedule_at` /
:meth:`EventLoop.schedule` return a cancel callable (the same handle pattern
:meth:`EventLoop.schedule_every` has always used), and events scheduled with
an ``owner`` can be revoked in bulk via :meth:`EventLoop.cancel_owner` — how
the fault layer kills the pending completion callbacks of an evicted edge
device or a blacked-out wire without the callbacks firing for an actor that
no longer exists.  A cancelled event is popped from the heap unexecuted and
does not advance the clock or the event budget.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple


class _Scheduled:
    """One heap entry; ``fn = None`` marks it cancelled (or already fired),
    which also releases the closure for GC."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable[[], None]):
        self.fn = fn

    def cancel(self) -> None:
        self.fn = None


class EventLoop:
    """Min-heap of ``(time, seq, event)``; ``seq`` makes ordering total."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)
        self._heap: List[Tuple[float, int, _Scheduled]] = []
        self._seq = itertools.count()
        self._processed = 0
        # owner -> its pending events (pruned lazily as they fire)
        self._owned: Dict[object, List[_Scheduled]] = {}

    def schedule_at(self, t: float, fn: Callable[[], None],
                    owner: Optional[object] = None) -> Callable[[], None]:
        """Schedule ``fn`` at virtual time ``t``; returns a cancel callable.
        ``owner`` registers the event for bulk revocation via
        :meth:`cancel_owner` (e.g. the device or wire whose completion this
        event represents)."""
        if t < self.now:
            raise ValueError(f"cannot schedule at {t} < now {self.now}")
        ev = _Scheduled(fn)
        heapq.heappush(self._heap, (float(t), next(self._seq), ev))
        if owner is not None:
            pending = self._owned.setdefault(owner, [])
            pending.append(ev)
            if len(pending) > 64:                     # lazy prune of fired
                pending[:] = [e for e in pending if e.fn is not None]
        return ev.cancel

    def schedule(self, delay: float, fn: Callable[[], None],
                 owner: Optional[object] = None) -> Callable[[], None]:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        return self.schedule_at(self.now + delay, fn, owner=owner)

    def cancel_owner(self, owner: object) -> int:
        """Cancel every pending event registered to ``owner``; returns the
        number of events revoked."""
        n = 0
        for ev in self._owned.pop(owner, []):
            if ev.fn is not None:
                ev.cancel()
                n += 1
        return n

    def schedule_every(self, interval: float, fn: Callable[[], None],
                       first_delay: Optional[float] = None) -> Callable[[], None]:
        """Fire ``fn`` every ``interval`` of virtual time until the returned
        cancel callable is invoked.  The periodic event re-arms itself, so a
        caller (e.g. the metrics sampler) MUST cancel it when the workload
        drains — otherwise :meth:`run` never sees an empty queue."""
        if interval <= 0:
            raise ValueError(f"non-positive interval {interval}")
        live = [True]

        def tick() -> None:
            if not live[0]:
                return
            fn()
            self.schedule(interval, tick)

        self.schedule(interval if first_delay is None else first_delay, tick)
        return lambda: live.__setitem__(0, False)

    def empty(self) -> bool:
        return not self._heap

    @property
    def events_processed(self) -> int:
        return self._processed

    def step(self) -> bool:
        """Fire the next event; returns False when the queue is empty.
        Cancelled events are discarded without running, counting against
        the budget, or advancing the clock."""
        while self._heap:
            t, _, ev = heapq.heappop(self._heap)
            if ev.fn is None:
                continue
            self.now = t
            self._processed += 1
            fn, ev.fn = ev.fn, None           # mark fired (prunable)
            fn()
            return True
        return False

    def run(self, until: Optional[float] = None,
            max_events: int = 10_000_000) -> float:
        """Drain the queue (or stop at virtual time ``until``); returns the
        final clock value."""
        while self._heap and self._processed < max_events:
            if until is not None and self._heap[0][0] > until:
                self.now = until
                return self.now
            self.step()
        if self._heap and any(ev.fn is not None for _, _, ev in self._heap):
            raise RuntimeError(f"event budget exhausted ({max_events})")
        return self.now
