"""Model-parallel stage plumbing that runs on ONE device: cost-model /
planner degree accounting, the bank's mesh-shape compile-cache keys, and the
timing-only simulator knobs.  Real multi-device numerics live in
tests/test_mesh_parity_subprocess.py."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.planner import select_split_online
from repro.core.profiler import GTX_1080TI, JETSON_TX2
from repro.models import transformer as tfm
from repro.runtime.simulator import SimConfig, run_sim
from repro.runtime.split_exec import CostModel, SplitModelBank


def _cfg():
    return get_config("qwen3-8b").reduced()


# ---------------------------------------------------------------------------
# per-stage estimates divide by the model-axis degree
# ---------------------------------------------------------------------------


def test_cost_model_divides_by_model_axis_degree():
    cfg = _cfg()
    base = CostModel(cfg, JETSON_TX2, GTX_1080TI)
    mp = CostModel(cfg, JETSON_TX2, GTX_1080TI, edge_mp=2, cloud_mp=4)
    assert mp.cloud_prefill_s(1, 32, 16) == \
        pytest.approx(base.cloud_prefill_s(1, 32, 16) / 4)
    assert mp.edge_prefill_s(1, 32, 16) == \
        pytest.approx(base.edge_prefill_s(1, 32, 16) / 2)
    assert mp.full_prefill_s(32, where="edge") == \
        pytest.approx(base.full_prefill_s(32, where="edge") / 2)
    assert mp.full_prefill_s(32, where="cloud") == \
        pytest.approx(base.full_prefill_s(32, where="cloud") / 4)
    assert mp.decode_step_s(2, where="cloud") == \
        pytest.approx(base.decode_step_s(2, where="cloud") / 4)
    assert mp.edge_decode_step_s(1, 16) == \
        pytest.approx(base.edge_decode_step_s(1, 16) / 2)
    assert mp.cloud_decode_step_s(1, 16) == \
        pytest.approx(base.cloud_decode_step_s(1, 16) / 4)
    # wire accounting is degree-invariant: only compute shards
    assert mp.payload_bytes("split", "int8", 32, 16, 1) == \
        base.payload_bytes("split", "int8", 32, 16, 1)


def test_planner_scores_match_model_parallel_cost_model():
    """The controller's selection phase must derate cloud compute by the
    same degree the simulator charges, or its picks drift from reality."""
    cfg = _cfg()
    kw = dict(candidate_splits=[1], edge=JETSON_TX2, cloud=GTX_1080TI,
              link_bytes_per_s=1e6)
    _, rows = select_split_online(cfg, 32, 16, **kw)
    _, rows4 = select_split_online(cfg, 32, 16, cloud_mp=4, **kw)
    assert rows4[0]["cloud_s"] == pytest.approx(rows[0]["cloud_s"] / 4)
    assert rows4[0]["edge_s"] == pytest.approx(rows[0]["edge_s"])
    assert rows4[0]["latency_s"] < rows[0]["latency_s"]


def test_tp_divisibility_check():
    cfg = _cfg()      # reduced: 4 heads, 2 kv heads
    defs = tfm.build_layer_defs(cfg)
    tfm.check_tp_divisibility(defs, cfg, 1)
    tfm.check_tp_divisibility(defs, cfg, 2)
    with pytest.raises(ValueError, match="kv heads"):
        tfm.check_tp_divisibility(defs, cfg, 4)


# ---------------------------------------------------------------------------
# mesh-shape compile-cache keys (regression guard for the PR 2 step cache)
# ---------------------------------------------------------------------------


def test_bank_mesh_shape_is_a_compile_cache_dimension():
    cfg = _cfg()
    bank = SplitModelBank(cfg, d_r=8)
    r = bank.runner(1)
    assert bank.runner(1) is r
    assert bank.runner(1, edge_mp=1, cloud_mp=1) is r
    # a different requested mesh shape is a different runner AND a different
    # compile-cache namespace — jitted steps must never alias across meshes
    r2 = bank.runner(1, cloud_mp=2)
    assert r2 is not r
    fn = bank._fn("decode", 1, 1)
    assert bank._fn("decode", 1, 1) is fn
    prompt = np.zeros((1, 8), np.int32)
    r.edge_half(r.params, prompt)
    assert any(k[:3] == ("edge", 1, 1) for k in bank.jit_cache_keys), \
        bank.jit_cache_keys


def test_bank_degree_needs_devices():
    """Asking for a model-axis degree beyond the local device count fails
    loudly at mesh build, not with a silent wrong-mesh fallback."""
    import jax
    mp = 2                                # smallest power of two > devices
    while mp <= jax.device_count():
        mp *= 2
    cfg = dataclasses.replace(_cfg(), num_heads=mp, num_kv_heads=mp)
    if cfg.d_ff % mp:
        pytest.skip(f"host exposes {jax.device_count()} devices; no "
                    f"divisible over-subscribed degree to request")
    bank = SplitModelBank(cfg, d_r=8)
    bank.runner(1, cloud_mp=mp)           # divisible, so runner exists...
    with pytest.raises(AssertionError, match="devices"):
        bank._fn("cloud", 1, mp)          # ...but the mesh cannot build


# ---------------------------------------------------------------------------
# timing-only simulator threading
# ---------------------------------------------------------------------------


def test_edge_mode_ignores_cloud_degree():
    """Mobile-only serving must not compile (or demand the devices of) the
    cloud's mesh: with cloud_mp=4 on this 1-device host, the edge-resident
    local engine runs at the edge degree and the sim completes."""
    cfg = dataclasses.replace(_cfg(), num_heads=8, num_kv_heads=4)
    tel = run_sim(SimConfig(cfg=cfg, mode="edge", cloud_mp=4, num_devices=2,
                            num_requests=4, prompt_len=12, max_new_tokens=2,
                            d_r=16, initial_split=1, seed=0))
    assert all(t.new_tokens == 2 for t in tel.traces)


def test_sim_timing_only_model_parallel_cloud_is_faster():
    cfg = dataclasses.replace(_cfg(), num_layers=4)
    base = dict(cfg=cfg, mode="split", num_devices=2, num_requests=8,
                arrival_rate=50.0, prompt_len=32, max_new_tokens=2,
                d_r=16, initial_split=1, numerics=False, seed=0)
    t1 = run_sim(SimConfig(**base))
    t4 = run_sim(SimConfig(**base, cloud_mp=4))
    lat1 = np.mean([t.latency_s for t in t1.traces])
    lat4 = np.mean([t.latency_s for t in t4.traces])
    assert lat4 < lat1, (lat4, lat1)
