"""Blockwise (flash) attention Pallas TPU kernel with GQA, causal and
sliding-window masking.

Grid: (B*N heads, num_q_blocks, num_k_blocks) with the k axis innermost
("arbitrary" semantics): running max/denominator/accumulator live in VMEM
scratch across k-block steps, initialized at k==0 and written back at the
final k block — the standard online-softmax structure, with block sizes
chosen so (block_q x d) + 2*(block_k x d) tiles fit VMEM and the matmul dims
are 128-multiples for the MXU.

GQA is handled in the BlockSpec index maps: query head h reads kv head
h // (N // K) — no repeat/materialization of K/V.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, seq_q: int,
                 seq_k: int, causal: bool, window: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions; queries are aligned to the end of the kv sequence
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, S, N, hd); k/v: (B, T, K, hd); returns (B, S, N, hd)."""
    B, S, N, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = N // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)

    # flatten (batch, head): row i -> batch i//N, q-head i%N, kv-head (i%N)//G
    qf = q.transpose(0, 2, 1, 3).reshape(B * N, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, hd)

    def q_index(i, qi, ki):
        return (i, qi, 0)

    def kv_index(i, qi, ki):
        return ((i // N) * K + (i % N) // G, ki, 0)

    grid = (B * N, S // block_q, T // block_k)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=1.0 / math.sqrt(hd), block_q=block_q,
            block_k=block_k, seq_q=S, seq_k=T, causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * N, S, hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),      # running max
            _vmem((block_q, 1), jnp.float32),      # running denominator
            _vmem((block_q, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, N, S, hd).transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
