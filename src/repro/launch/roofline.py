"""Roofline analysis from compiled AOT artifacts (DESIGN.md / assignment):

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` reports the *per-device* program, so the chips
factor cancels for compute/memory; collective bytes are parsed from the
post-SPMD optimized HLO text (collectives only exist after partitioning).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment constants).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every `dtype[dims]` group in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind bytes summed over the program (one device's view).

    For each collective instruction we count the *output* tensor bytes (the
    data that moved to this device); `-start` variants are counted, `-done`
    skipped to avoid double counting."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w-]+)\(", line)
        if not m:
            continue
        type_str, opname = m.groups()
        base = opname
        if base.endswith("-start"):
            base = base[:-6]
        elif base.endswith("-done"):
            continue
        if base in COLLECTIVE_OPS:
            out[base] += _shape_bytes(type_str)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: int
    collectives: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    memory_analysis: Dict[str, float] = field(default_factory=dict)
    note: str = ""

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
                f"{self.collective_s*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} |")


def analyze(arch: str, shape: str, mesh_name: str, chips: int, compiled,
            model_flops: float, note: str = "") -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_total = sum(coll.values())

    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll_total / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    total_flops = flops * chips
    useful = model_flops / total_flops if total_flops else 0.0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = float(v)
    except Exception:                   # pragma: no cover
        pass

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes_per_device=coll_total, collectives=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops, useful_ratio=useful,
        memory_analysis=mem, note=note)


def to_dict(r: RooflineReport) -> dict:
    return asdict(r)
