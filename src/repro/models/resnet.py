"""Paper-faithful ResNet-50 (He et al. 2015) in pure JAX, with the butterfly
unit insertable after any of the 16 residual blocks — exactly the paper's
Fig. 4/6 setup.

Deviation noted in DESIGN.md: BatchNorm is replaced by GroupNorm(32) so the
model is stateless (no running stats to thread through pjit); this does not
change the butterfly mechanics the paper studies.  The butterfly unit is the
paper's literal form: 1x1 conv C -> D_r (reduction, edge side), int8 wire
quantization, 1x1 conv D_r -> C (restoration, cloud side), trained
end-to-end via the straight-through fake-quant.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.resnet50 import ResNetConfig
from repro.core.quantization import fake_quant


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout)) * \
        math.sqrt(2.0 / fan_in)


def conv(x, w, stride: int = 1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(x, scale, bias, groups: int = 32, eps: float = 1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, H, W, C) * scale + bias).astype(x.dtype)


def _norm_params(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def max_pool(x, window=3, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "SAME")


# ---------------------------------------------------------------------------
# bottleneck residual block
# ---------------------------------------------------------------------------


def init_block(key, cin, cout, stride):
    mid = cout // 4
    ks = jax.random.split(key, 4)
    p = {
        "conv1": _conv_init(ks[0], 1, 1, cin, mid), "n1": _norm_params(mid),
        "conv2": _conv_init(ks[1], 3, 3, mid, mid), "n2": _norm_params(mid),
        "conv3": _conv_init(ks[2], 1, 1, mid, cout), "n3": _norm_params(cout),
    }
    if cin != cout or stride != 1:
        p["proj"] = _conv_init(ks[3], 1, 1, cin, cout)
        p["np"] = _norm_params(cout)
    return p


def apply_block(p, x, stride):
    h = jax.nn.relu(group_norm(conv(x, p["conv1"]), **p["n1"]))
    h = jax.nn.relu(group_norm(conv(h, p["conv2"], stride), **p["n2"]))
    h = group_norm(conv(h, p["conv3"]), **p["n3"])
    if "proj" in p:
        x = group_norm(conv(x, p["proj"], stride), **p["np"])
    return jax.nn.relu(x + h)


# ---------------------------------------------------------------------------
# butterfly unit (paper Fig. 1/2: 1x1 conv down, wire, 1x1 conv up)
# ---------------------------------------------------------------------------


def init_butterfly_conv(key, c, d_r):
    k1, k2 = jax.random.split(key)
    return {"reduce": _conv_init(k1, 1, 1, c, d_r),
            "restore": _conv_init(k2, 1, 1, d_r, c)}


def apply_butterfly_conv(p, x, wire_bits=8, train=True):
    r = conv(x, p["reduce"])
    r = fake_quant(r, wire_bits)          # straight-through int8 wire
    return conv(r, p["restore"])


# ---------------------------------------------------------------------------
# full network
# ---------------------------------------------------------------------------


def init_resnet(key, cfg: ResNetConfig):
    ks = iter(jax.random.split(key, 64))
    params = {
        "stem": _conv_init(next(ks), 7, 7, 3, cfg.stem_channels),
        "stem_n": _norm_params(cfg.stem_channels),
        "blocks": [],
        "head": jax.random.truncated_normal(
            next(ks), -2, 2, (cfg.stages[-1][1], cfg.num_classes)) *
            math.sqrt(1.0 / cfg.stages[-1][1]),
    }
    cin = cfg.stem_channels
    for si, (blocks, cout) in enumerate(cfg.stages):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            params["blocks"].append(init_block(next(ks), cin, cout, stride))
            cin = cout
    if cfg.butterfly is not None:
        c = cfg.block_channels()[cfg.butterfly.layer - 1]
        params["butterfly"] = init_butterfly_conv(next(ks), c, cfg.butterfly.d_r)
    return params


def forward_resnet(params, images, cfg: ResNetConfig, train: bool = True):
    """images: (B, H, W, 3) -> logits (B, num_classes)."""
    x = max_pool(jax.nn.relu(group_norm(conv(images, params["stem"], 2),
                                        **params["stem_n"])))
    bidx = 0
    for si, (blocks, cout) in enumerate(cfg.stages):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = apply_block(params["blocks"][bidx], x, stride)
            bidx += 1
            if cfg.butterfly is not None and bidx == cfg.butterfly.layer:
                x = apply_butterfly_conv(params["butterfly"], x,
                                         cfg.butterfly.wire_bits, train)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]


def edge_cloud_split(params, images, cfg: ResNetConfig):
    """Run the split explicitly: edge half returns the quantized wire tensor,
    cloud half consumes it — used by the split-serving example and tests."""
    from repro.core.quantization import dequantize, quantize
    assert cfg.butterfly is not None
    x = max_pool(jax.nn.relu(group_norm(conv(images, params["stem"], 2),
                                        **params["stem_n"])))
    bidx = 0
    blocks_meta = []
    for si, (blocks, cout) in enumerate(cfg.stages):
        for bi in range(blocks):
            blocks_meta.append(2 if (bi == 0 and si > 0) else 1)
    # edge
    for b in range(cfg.butterfly.layer):
        x = apply_block(params["blocks"][b], x, blocks_meta[b])
    r = conv(x, params["butterfly"]["reduce"])
    codes, scales = quantize(r, cfg.butterfly.wire_bits)
    wire = {"codes": codes, "scales": scales}       # <- the only offloaded data
    # cloud
    r = dequantize(wire["codes"], wire["scales"], x.dtype)
    x = conv(r, params["butterfly"]["restore"])
    for b in range(cfg.butterfly.layer, cfg.num_blocks):
        x = apply_block(params["blocks"][b], x, blocks_meta[b])
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"], wire
