"""End-to-end system behaviour + roofline/dry-run plumbing units."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, supports_shape
from repro.configs.all import ASSIGNED
from repro.core import costs
from repro.launch import roofline


def test_assigned_pool_complete():
    assert len(ASSIGNED) == 10
    types = {get_config(a).arch_type for a in ASSIGNED}
    assert types == {"dense", "moe", "vlm", "audio", "ssm", "hybrid"}


def test_input_shapes_assigned():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long500k_support_matrix():
    """DESIGN.md section 5: ssm/hybrid + windowed gemma3 run long_500k; pure
    full-attention archs are skipped with a documented reason."""
    runs, skips = [], []
    for a in ASSIGNED:
        ok, why = supports_shape(get_config(a), INPUT_SHAPES["long_500k"])
        (runs if ok else skips).append(a)
    assert set(runs) == {"xlstm-125m", "zamba2-7b", "gemma3-12b"}
    assert len(skips) == 7


def test_config_exactness():
    """Every assigned config matches the assignment block exactly."""
    expect = {
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch, (L, d, H, kv, ff, V) in expect.items():
        c = get_config(arch)
        got = (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
               c.vocab_size)
        assert got == (L, d, H, kv, ff, V), (arch, got)
        assert c.source, f"{arch} must cite its source"


def test_param_counts_sane():
    """Param accounting lands near the advertised model sizes."""
    approx = {
        "qwen3-8b": 8e9, "qwen3-14b": 14e9, "gemma-7b": 8.5e9,
        "gemma3-12b": 12e9, "pixtral-12b": 12e9,
        "qwen3-moe-235b-a22b": 235e9, "llama4-maverick-400b-a17b": 400e9,
        "xlstm-125m": 125e6, "zamba2-7b": 7e9,
    }
    for arch, n in approx.items():
        got = costs.param_count(get_config(arch))
        assert 0.55 * n < got < 1.6 * n, (arch, got / 1e9)
    active = costs.param_count(get_config("qwen3-moe-235b-a22b"),
                               active_only=True)
    assert 12e9 < active < 30e9       # A22B


def test_roofline_collective_parser():
    hlo = """
  %ag = bf16[16,4096,5120] all-gather(bf16[1,4096,5120] %x), dimensions={0}
  %ar.1 = f32[128] all-reduce(f32[128] %y), to_apply=%sum
  %rs = (f32[64], f32[64]) reduce-scatter(f32[1024] %z, f32[1024] %w)
  %cp-start = bf16[2,8] collective-permute-start(bf16[2,8] %a)
  %cp-done = bf16[2,8] collective-permute-done(%cp-start)
  %dot = f32[4,4] dot(f32[4,8] %p, f32[8,4] %q)
"""
    got = roofline.collective_bytes(hlo)
    assert got["all-gather"] == 16 * 4096 * 5120 * 2
    assert got["all-reduce"] == 128 * 4
    assert got["reduce-scatter"] == 64 * 4 * 2
    assert got["collective-permute"] == 2 * 8 * 2          # start counted once
    assert got["all-to-all"] == 0


def test_roofline_terms_math():
    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 197e12, "bytes accessed": 819e9}

        def as_text(self):
            return "%ar = f32[125000000] all-reduce(f32[125000000] %x)\n"

        def memory_analysis(self):
            raise RuntimeError("n/a")

    rep = roofline.analyze("a", "s", "16x16", 256, FakeCompiled(),
                           model_flops=197e12 * 256)
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(1.0)
    assert rep.collective_s == pytest.approx(0.01)
    assert rep.useful_ratio == pytest.approx(1.0)
    assert rep.bottleneck in ("compute", "memory")


def test_dryrun_results_if_present():
    """When the sweep has produced artifacts, validate their invariants."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts yet")
    seen = 0
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        if "compute_s" not in rec:      # skips, errors, pipeline artifacts
            continue
        seen += 1
        assert rec["compute_s"] >= 0 and rec["memory_s"] >= 0
        assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert seen > 0
