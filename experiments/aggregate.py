"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python experiments/aggregate.py [--dir experiments/dryrun]
Prints: the section-Dry-run table, the section-Roofline table (single-pod),
and the multi-pod compile-proof matrix.
"""
from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict

ARCH_ORDER = ["qwen3-14b", "llama4-maverick-400b-a17b", "qwen3-moe-235b-a22b",
              "pixtral-12b", "whisper-base", "gemma-7b", "gemma3-12b",
              "qwen3-8b", "xlstm-125m", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    recs = {}
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, f)))
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        tag = f.rsplit("_", 1)[-1].replace(".json", "")
        recs.setdefault(key, []).append((f, r))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB" if b > 1e9 else f"{b/1e6:.1f}MB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)

    def get(arch, shape, mesh):
        lst = recs.get((arch, shape, mesh), [])
        # prefer untagged baseline files
        for f, r in lst:
            if f == f"{arch}_{shape}_{mesh.replace('x','-')}.json":
                return r
        return lst[0][1] if lst else None

    print("### Dry-run matrix (compile status, peak device memory)\n")
    print("| arch | shape | 16x16 | 2x16x16 |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cells = []
            for mesh in ("16x16", "2x16x16"):
                r = get(a, s, mesh)
                if r is None:
                    cells.append("(missing)")
                elif "skipped" in r:
                    cells.append("skip (documented)")
                elif "error" in r:
                    cells.append("ERROR")
                else:
                    peak = r.get("memory_analysis", {}).get("peak_memory_in_bytes")
                    if peak is None:
                        peak = (r.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
                                + r.get("memory_analysis", {}).get("argument_size_in_bytes", 0))
                    cells.append(f"OK {fmt_bytes(peak)} ({r['compile_s']:.0f}s)")
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod 16x16, per-device terms, seconds)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| MODEL_FLOPS/HLO_FLOPS | collectives |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = get(a, s, "16x16")
            if not r or "compute_s" not in r:
                continue
            coll = ", ".join(f"{k.split('-')[-1] if False else k}={fmt_bytes(v)}"
                             for k, v in sorted(r.get("collectives", {}).items())
                             if v)
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                  f"| {r['collective_s']:.4f} | {r['bottleneck']} "
                  f"| {r['useful_ratio']:.2f} | {coll or '-'} |")

    missing = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = get(a, s, mesh)
                if r is None or "error" in r:
                    missing.append((a, s, mesh))
    n_ok = sum(1 for lst in recs.values() for f, r in lst if "compute_s" in r)
    print(f"\nartifacts: {n_ok} compiled records; outstanding: {missing if missing else 'none'}")


if __name__ == "__main__":
    main()
