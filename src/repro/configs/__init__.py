from repro.configs.base import (
    ButterflyConfig,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    XLSTMConfig,
    get_config,
    list_archs,
    supports_shape,
)

__all__ = [
    "ButterflyConfig", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "MoEConfig", "SSMConfig", "XLSTMConfig", "get_config", "list_archs",
    "supports_shape",
]
