"""MoE layer: routing/dispatch invariants, no-drop equivalence with a dense
per-token loop oracle, capacity dropping, aux losses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property suites need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import moe as moe_lib
from repro.models.parallel import LOCAL


def _cfg(top_k=2, experts=4, cf=100.0):
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, top_k=top_k, num_experts=experts, capacity_factor=cf))


def _dense_oracle(params, x, cfg):
    """Per-token loop: run every token through its top-k experts densely."""
    m = cfg.moe
    B, S, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float32)
    logits = xf @ np.asarray(params["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    gate, eids = jax.lax.top_k(probs, m.top_k)
    gate = np.asarray(gate / jnp.sum(gate, -1, keepdims=True))
    eids = np.asarray(eids)
    wg = np.asarray(params["wg"], np.float32)
    wu = np.asarray(params["wu"], np.float32)
    wd = np.asarray(params["wd"], np.float32)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(m.top_k):
            e = eids[t, j]
            g = xf[t] @ wg[e]
            u = xf[t] @ wu[e]
            h = (g * jax.nn.sigmoid(jnp.asarray(g)) * u) if False else None
            act = np.asarray(jax.nn.silu(jnp.asarray(g))) * u
            out[t] += gate[t, j] * (act @ wd[e])
    return out.reshape(B, S, d)


def test_moe_matches_dense_oracle_no_drop():
    cfg = _cfg(top_k=2, experts=4, cf=100.0)
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.5
    out, aux = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    oracle = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=2e-4, atol=2e-4)


def test_capacity_dropping_drops_tokens():
    """With capacity_factor ~0, outputs collapse toward zero (all dropped)."""
    cfg = _cfg(top_k=1, experts=4, cf=100.0)
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    full, _ = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    cfg_tight = _cfg(top_k=1, experts=4, cf=1e-9)
    tight, _ = moe_lib.apply_moe(params, x, cfg=cfg_tight, pctx=LOCAL, act="silu")
    # capacity 1: almost everything dropped
    assert float(jnp.mean(jnp.abs(tight))) < float(jnp.mean(jnp.abs(full)))


def test_aux_losses_finite_and_scaled():
    cfg = _cfg()
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    _, aux = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    assert float(aux["load_balance"]) > 0
    assert float(aux["router_z"]) >= 0


def test_balanced_router_minimizes_lb_loss():
    """Uniform routing yields load-balance loss ~= coefficient (E*1/E*1/E*E)."""
    cfg = _cfg(top_k=1, experts=4)
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model))
    _, aux = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    lb = float(aux["load_balance"]) / cfg.moe.load_balance_coef
    assert lb == pytest.approx(1.0, rel=0.3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_dispatch_conservation(top_k, seed):
    """Every kept (token, slot) contributes exactly gate_j * expert(x_t)."""
    cfg = _cfg(top_k=top_k, experts=4, cf=100.0)
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(seed), (1, 8, cfg.d_model)) * 0.3
    out, _ = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    oracle = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), oracle, rtol=5e-4, atol=5e-4)


def test_shared_expert_added():
    cfg = get_config("llama4-maverick-400b-a17b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=100.0))
    params, _ = moe_lib.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 4, cfg.d_model)) * 0.3
    with_shared, _ = moe_lib.apply_moe(params, x, cfg=cfg, pctx=LOCAL, act="silu")
    no_shared_cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, shared_expert_ff=0))
    without, _ = moe_lib.apply_moe(params, x, cfg=no_shared_cfg, pctx=LOCAL,
                                   act="silu")
    assert float(jnp.max(jnp.abs(with_shared - without))) > 1e-6
