"""Adaptive split control — the paper's Sec. III-C load-adaptation protocol,
closed-loop.

Periodically (the mobile "pings the server"), the controller samples the
cloud's congestion level and the uplink's *observed* goodput (nominal
bandwidth derated by contention, over the Wire's trailing window) and
re-runs Algorithm 1's selection phase (core/planner.select_split_online)
over the hosted partition points.  New requests are then routed to the
winning split: congestion pushes the split deeper — more layers stay on the
edge — while still shipping less than the raw input.

When ``transport_mode="auto"`` the selection phase also scores both decode
transports per split — cache handoff's prompt-proportional KV bytes vs the
streamed transport's per-token RTT x ``new_tokens`` — and the controller
routes new arrivals to the winning (split, transport) pair.

In a multi-cell topology each cell runs its OWN controller instance against
its own Wire and device class (``cell`` labels its decisions); all
instances observe the same shared CloudServer load, so cross-cell
congestion is the coupling signal.  ``objective`` names a registered
selection objective (planner.SELECTION_OBJECTIVES) — ``latency``,
``energy``, or ``energy_under_slo`` with ``slo_s``.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.planner import select_split_online
from repro.core.profiler import HardwareProfile
from repro.runtime.clock import EventLoop
from repro.runtime.telemetry import ControlDecision, Telemetry
from repro.runtime.tracing import NULL_TRACER
from repro.runtime.wire import Wire


class AdaptiveSplitController:
    def __init__(self, *, loop: EventLoop, uplink: Wire,
                 cloud_load: Callable[[float], float],
                 cfg, d_r: int, seq: int,
                 candidate_splits: Sequence[int],
                 edge: HardwareProfile, cloud: HardwareProfile,
                 wire_mode: str, telemetry: Telemetry,
                 set_split: Callable[[int], None],
                 get_split: Callable[[], int],
                 interval_s: float = 0.05,
                 handoff_bytes_per_layer: float = 0.0,
                 objective: str = "latency",
                 slo_s: Optional[float] = None,
                 transport_mode: str = "cache_handoff",
                 new_tokens: int = 1,
                 set_transport: Optional[Callable[[str], None]] = None,
                 get_transport: Optional[Callable[[], str]] = None,
                 edge_mp: int = 1, cloud_mp: int = 1,
                 cell: str = "cell0", tracer=NULL_TRACER):
        # "auto" keeps scoring the classic pair; "progressive" is explicitly
        # selectable so existing auto-routed trajectories stay byte-identical
        assert transport_mode in ("cache_handoff", "streamed", "progressive",
                                  "auto"), transport_mode
        self.handoff_bytes_per_layer = handoff_bytes_per_layer
        self.cell = cell
        self.slo_s = slo_s
        # score with the same model-axis degrees the CostModel charges, so
        # the controller's picks stay consistent with simulated durations
        self.edge_mp = edge_mp
        self.cloud_mp = cloud_mp
        self.loop = loop
        self.uplink = uplink
        self.cloud_load = cloud_load
        self.cfg = cfg
        self.d_r = d_r
        self.seq = seq
        self.candidates = list(candidate_splits)
        self.edge = edge
        self.cloud = cloud
        self.wire_mode = wire_mode
        self.telemetry = telemetry
        self.set_split = set_split
        self.get_split = get_split
        self.interval_s = interval_s
        self.objective = objective
        self.transport_mode = transport_mode
        self.new_tokens = new_tokens
        self.set_transport = set_transport
        self.get_transport = get_transport or (lambda: "cache_handoff")
        self.tracer = tracer
        self.running = False

    def start(self) -> None:
        self.running = True
        self.loop.schedule(0.0, self._tick)

    def stop(self) -> None:
        self.running = False

    def poke(self, now: float, reason: str = "poke") -> None:
        """Out-of-band re-score (e.g. the fault layer after a link
        handover): decide immediately instead of waiting for the tick."""
        if self.running:
            self.decide(now, reason=reason)

    def decide(self, now: float, reason: str = "tick") -> int:
        load = self.cloud_load(now)
        link_bps = self.uplink.observed_bytes_per_s(now)
        transports = ("cache_handoff", "streamed") \
            if self.transport_mode == "auto" else (self.transport_mode,)
        best, _ = select_split_online(
            self.cfg, self.seq, self.d_r,
            candidate_splits=self.candidates,
            edge=self.edge, cloud=self.cloud,
            link_bytes_per_s=link_bps, cloud_load=load,
            wire_mode=self.wire_mode,
            link_energy_mj_per_byte=self.uplink.transfer_energy_mj(1.0),
            handoff_bytes_per_layer=self.handoff_bytes_per_layer,
            objective=self.objective, slo_s=self.slo_s,
            transports=transports, new_tokens=self.new_tokens,
            downlink_bytes_per_s=self.uplink.observed_down_bytes_per_s(now),
            downlink_energy_mj_per_byte=self.uplink.downlink_energy_mj(1.0),
            edge_mp=self.edge_mp, cloud_mp=self.cloud_mp)
        old = self.get_split()
        self.telemetry.record_decision(ControlDecision(
            t=now, cloud_load=load, link_bytes_per_s=link_bps,
            old_split=old, new_split=best["split"],
            transport=best["transport"], cell=self.cell, reason=reason))
        self.tracer.instant(
            f"ctl/{self.cell}", "decision", now, cat="control",
            args={"split": best["split"], "transport": best["transport"],
                  "cloud_load": load, "link_bytes_per_s": link_bps,
                  "reason": reason})
        if best["split"] != old:
            self.set_split(best["split"])
        if self.set_transport is not None and \
                best["transport"] != self.get_transport():
            self.set_transport(best["transport"])
        return best["split"]

    def _tick(self) -> None:
        if not self.running:
            return
        self.decide(self.loop.now)
        self.loop.schedule(self.interval_s, self._tick)
