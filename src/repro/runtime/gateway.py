"""Serving gateway in front of the cloud: SLO classes, admission control,
resilience, and autoscaling (DESIGN.md section 17).

The paper motivates the split by the "considerable computational and
communication load" offloading imposes on the cloud server — this module
models the serving front-end that load actually hits.  A
:class:`Gateway` wraps the :class:`~repro.runtime.actors.CloudServer`'s
ingress with:

  * a priority job queue (:class:`JobQueue`) — ``interactive`` requests are
    never queued behind ``batch`` ones (the SLO class rides on the
    :class:`~repro.runtime.simulator.Arrival` and into the
    :class:`~repro.runtime.telemetry.RequestTrace`),
  * admission control that sheds a request at payload arrival when the
    predicted queue delay would violate its class SLO
    (``outcome="shed"``; telemetry conserves done+failed+shed == submitted),
  * per-cell circuit breakers (:class:`CircuitBreaker`) with half-open
    recovery, driven by the existing fault/health signals (request
    outcomes + outage-dropped payloads),
  * hedged retries for interactive requests (a duplicate payload send races
    the first; the cloud dedupes whichever lands second),
  * an LRU response cache (:class:`ResponseCache`) keyed on the prompt —
    hits return the byte-identical generated ids without touching the
    accelerator (``gateway_cache_hits``),
  * autoscaling cloud replicas with modeled spin-up lag; the replica count
    grows the slot pool and feeds ``CloudServer.current_load``.

Every knob lives on one frozen :class:`GatewayPolicy`.  The default policy
is ALL-OFF: a run with ``SimConfig(gateway=GatewayPolicy())`` is
byte-identical to ``gateway=None`` (asserted in tests/test_gateway.py, the
same contract the fault layer makes for ``faults=None``), and every
decision is a function of virtual-clock state, so chaos + gateway runs
record -> replay byte-identically.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

SLO_CLASSES = ("interactive", "batch")


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GatewayPolicy:
    """All gateway knobs, one frozen dataclass.  The default (everything
    off) reproduces the legacy infinite-queue FIFO byte-for-byte; each
    feature is opt-in.  ``parse`` accepts the CLI grammar — a comma list of
    flags / ``key=value`` pairs, e.g.
    ``"priority,shed,slo=40/400,reserve=1,cache=64,hedge=0.03,breaker,autoscale"``.
    """
    # priority queue: interactive ranks ahead of batch
    priority: bool = False
    # admission control: shed when predicted queue delay > the class SLO
    shed: bool = False
    slo_interactive_ms: float = 250.0
    slo_batch_ms: Optional[float] = 2000.0   # None = batch never shed
    reserved_slots: int = 0                  # slots batch may not occupy
    # per-cell circuit breakers (closed -> open -> half_open -> closed)
    breaker: bool = False
    breaker_fail_threshold: int = 3          # consecutive failures to open
    breaker_halfopen_after_s: float = 0.5    # open -> half_open cooldown
    breaker_probes: int = 2                  # successes to close again
    # hedged retries: duplicate an interactive payload send still stuck in
    # the uplink phase after this long (the cloud drops the loser)
    hedge: bool = False
    hedge_delay_s: float = 0.05
    # LRU response cache (numerics mode: keyed on prompt ids; 0 = off)
    cache_size: int = 0
    # autoscaling replicas: each replica adds a max_concurrent-sized slot
    # pool after spin_up_s; scale-down is immediate once the tail drains
    autoscale: bool = False
    max_replicas: int = 4
    scale_up_load: float = 0.85
    scale_down_load: float = 0.30
    spin_up_s: float = 0.25
    autoscale_interval_s: float = 0.05

    def __post_init__(self):
        assert self.breaker_fail_threshold >= 1
        assert self.breaker_probes >= 1
        assert self.max_replicas >= 1
        assert 0 <= self.scale_down_load < self.scale_up_load <= 1.0

    @property
    def slo_s(self) -> Dict[str, Optional[float]]:
        return {"interactive": self.slo_interactive_ms / 1e3,
                "batch": self.slo_batch_ms / 1e3
                if self.slo_batch_ms is not None else None}

    @classmethod
    def parse(cls, spec: str) -> "GatewayPolicy":
        kw: Dict[str, object] = {}
        for part in (p.strip() for p in spec.split(",") if p.strip()):
            key, _, val = part.partition("=")
            if key in ("priority", "shed", "breaker", "hedge", "autoscale"):
                kw[key] = True
                if key == "hedge" and val:
                    kw["hedge_delay_s"] = float(val)
            elif key == "slo":
                inter, _, batch = val.partition("/")
                kw["slo_interactive_ms"] = float(inter)
                kw["slo_batch_ms"] = float(batch) if batch and \
                    batch != "inf" else None
                kw["shed"] = True
            elif key == "reserve":
                kw["reserved_slots"] = int(val)
            elif key == "cache":
                kw["cache_size"] = int(val)
            elif key == "replicas":
                kw["max_replicas"] = int(val)
                kw["autoscale"] = True
            elif key == "spinup":
                kw["spin_up_s"] = float(val)
            else:
                raise ValueError(
                    f"bad gateway spec token {part!r}: expected "
                    f"priority|shed|breaker|hedge[=delay_s]|autoscale|"
                    f"slo=<int_ms>/<batch_ms|inf>|reserve=<n>|cache=<n>|"
                    f"replicas=<n>|spinup=<s>")
        return cls(**kw)


# ---------------------------------------------------------------------------
# priority job queue
# ---------------------------------------------------------------------------


class JobQueue:
    """The cloud's pending queue: FIFO by default, (class-rank, arrival-seq)
    when ``priority`` is on — so an interactive request is NEVER queued
    behind a batch one, while ties stay strictly FIFO.  Implements the
    deque surface the server and fault layer use (append/popleft/peek/
    remove/clear/contains/len/iter); removal is O(1) via tombstones."""

    def __init__(self, priority: bool = False):
        self.priority = priority
        self._heap: List[list] = []          # [rank, seq, req, alive]
        self._entries: Dict[int, list] = {}  # uid -> heap entry
        self._seq = 0

    def _rank(self, req) -> int:
        if not self.priority:
            return 0
        return 0 if req.trace.slo_class == "interactive" else 1

    def append(self, req) -> None:
        e = [self._rank(req), self._seq, req, True]
        self._seq += 1
        self._entries[req.trace.uid] = e
        heapq.heappush(self._heap, e)

    def _prune(self) -> None:
        while self._heap and not self._heap[0][3]:
            heapq.heappop(self._heap)

    def peek(self):
        self._prune()
        return self._heap[0][2] if self._heap else None

    def popleft(self):
        self._prune()
        if not self._heap:
            raise IndexError("pop from an empty JobQueue")
        e = heapq.heappop(self._heap)
        e[3] = False
        del self._entries[e[2].trace.uid]
        return e[2]

    def remove(self, req) -> None:
        e = self._entries.pop(req.trace.uid, None)
        if e is None:
            raise ValueError(f"request {req.trace.uid} not queued")
        e[3] = False

    def clear(self) -> None:
        for e in self._entries.values():
            e[3] = False
        self._entries.clear()

    def __contains__(self, req) -> bool:
        return req.trace.uid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def __iter__(self):
        return iter([e[2] for e in
                     sorted(self._entries.values(), key=lambda e: e[:2])])


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Per-cell breaker: ``closed`` (serving) -> ``open`` after
    ``fail_threshold`` consecutive failures (requests from the cell are
    shed instead of queued) -> ``half_open`` after the cooldown (admit up
    to ``probes`` trial requests) -> ``closed`` again once that many
    successes land; any half-open failure re-opens.  Pure virtual-time
    state machine — every transition is a function of (event, now)."""

    def __init__(self, fail_threshold: int, halfopen_after_s: float,
                 probes: int):
        self.fail_threshold = fail_threshold
        self.halfopen_after_s = halfopen_after_s
        self.probes = probes
        self.state = "closed"
        self.failures = 0                    # consecutive, while closed
        self.opened_at = float("-inf")
        self._probe_budget = 0
        self._probe_successes = 0

    def _maybe_half_open(self, now: float) -> None:
        if self.state == "open" and \
                now >= self.opened_at + self.halfopen_after_s:
            self.state = "half_open"
            self._probe_budget = self.probes
            self._probe_successes = 0

    def allow(self, now: float) -> bool:
        """May a request from this cell enter the queue at ``now``?"""
        self._maybe_half_open(now)
        if self.state == "closed":
            return True
        if self.state == "half_open" and self._probe_budget > 0:
            self._probe_budget -= 1
            return True
        return False

    def record_success(self, now: float) -> bool:
        """Returns True when this success CLOSES a half-open breaker."""
        self._maybe_half_open(now)
        if self.state == "half_open":
            self._probe_successes += 1
            if self._probe_successes >= self.probes:
                self.state = "closed"
                self.failures = 0
                return True
        elif self.state == "closed":
            self.failures = 0
        return False

    def record_failure(self, now: float) -> bool:
        """Returns True when this failure OPENS the breaker."""
        self._maybe_half_open(now)
        if self.state == "half_open":
            self.state = "open"
            self.opened_at = now
            return True
        if self.state == "closed":
            self.failures += 1
            if self.failures >= self.fail_threshold:
                self.state = "open"
                self.opened_at = now
                return True
        return False

    def is_open(self, now: float) -> bool:
        self._maybe_half_open(now)
        return self.state == "open"


# ---------------------------------------------------------------------------
# LRU response cache
# ---------------------------------------------------------------------------


class ResponseCache:
    """LRU over (prompt ids, max_new_tokens) -> generated ids.  Only
    meaningful in numerics mode (timing-only arrivals carry no prompt);
    a hit replays the byte-identical response without accelerator time."""

    def __init__(self, size: int):
        self.size = size
        self._od: "OrderedDict[Tuple, Tuple[int, ...]]" = OrderedDict()

    @staticmethod
    def key(req) -> Optional[Tuple]:
        if req.tokens is None:
            return None
        return (req.tokens.tobytes(), req.max_new_tokens)

    def get(self, key) -> Optional[Tuple[int, ...]]:
        if key is None or key not in self._od:
            return None
        self._od.move_to_end(key)
        return self._od[key]

    def put(self, key, ids) -> None:
        if key is None or self.size <= 0:
            return
        self._od[key] = tuple(int(x) for x in ids)
        self._od.move_to_end(key)
        while len(self._od) > self.size:
            self._od.popitem(last=False)

    def __len__(self) -> int:
        return len(self._od)


# ---------------------------------------------------------------------------
# the gateway
# ---------------------------------------------------------------------------


class Gateway:
    """Attaches to a CloudServer: swaps its pending deque for the policy's
    JobQueue and intercepts ingress/egress.  With the default all-off
    policy every hook degenerates to the legacy path."""

    def __init__(self, policy: GatewayPolicy, *, loop, server, telemetry):
        self.policy = policy
        self.loop = loop
        self.server = server
        self.telemetry = telemetry
        self.queue = JobQueue(priority=policy.priority)
        self.cache = ResponseCache(policy.cache_size)
        self.breakers: Dict[str, CircuitBreaker] = {}
        # per-cell controller pokes (simulator wires these): breaker
        # transitions nudge the cell's split controller off-cycle, the same
        # reactive path link handovers use
        self.pokes: Dict[str, Callable[[float, str], None]] = {}
        self._svc_ewma: Optional[float] = None   # observed cloud service time
        self._target_replicas = 1
        self._cancel_autoscale: Optional[Callable[[], None]] = None
        assert policy.reserved_slots < server.max_concurrent, \
            f"reserved_slots={policy.reserved_slots} leaves no slot a " \
            f"batch request may ever take (pool size " \
            f"{server.max_concurrent}) — the queue would deadlock"
        server.gateway = self
        server.pending = self.queue

    # -- wiring -------------------------------------------------------------
    def start(self) -> None:
        if self.policy.autoscale:
            self._cancel_autoscale = self.loop.schedule_every(
                self.policy.autoscale_interval_s, self._autoscale_tick)

    def stop(self) -> None:
        if self._cancel_autoscale is not None:
            self._cancel_autoscale()
            self._cancel_autoscale = None

    def _breaker(self, cell: str) -> CircuitBreaker:
        if cell not in self.breakers:
            p = self.policy
            self.breakers[cell] = CircuitBreaker(
                p.breaker_fail_threshold, p.breaker_halfopen_after_s,
                p.breaker_probes)
        return self.breakers[cell]

    def cell_load_fn(self, cell: str) -> Callable[[float], float]:
        """The load signal a cell's controller should read: the shared
        cloud occupancy, ceilinged while this cell's breaker is open (the
        cloud is unreachable FOR THIS CELL, so its controller routes
        edge-heavy — the same signal shape a cloud outage produces)."""
        def load(now: float) -> float:
            if self.policy.breaker and self._breaker(cell).is_open(now):
                return 0.99
            return self.server.current_load(now)
        return load

    # -- ingress ------------------------------------------------------------
    def admit(self, req) -> bool:
        """Gate one payload arrival.  Returns True to enqueue; False when
        the gateway fully handled it (cache hit, breaker shed, admission
        shed)."""
        now = self.loop.now
        t = req.trace
        hit = self.cache.get(self.cache.key(req))
        if hit is not None:
            self._serve_cached(req, hit, now)
            return False
        if self.policy.breaker and not self._breaker(t.cell).allow(now):
            self.telemetry.counters["gateway_breaker_shed"] += 1
            self._shed(req, "breaker_open", now)
            return False
        if self.policy.shed:
            slo = self.policy.slo_s[t.slo_class]
            if slo is not None and \
                    self.predicted_delay_s(t.slo_class, now) > slo:
                self._shed(req, "admission", now)
                return False
        return True

    def may_start(self, req, free_slots: int) -> bool:
        """May the queue head enter a slot?  Interactive always; batch only
        when it would leave ``reserved_slots`` free ones behind."""
        if req.trace.slo_class == "interactive":
            return True
        return free_slots > self.policy.reserved_slots

    def predicted_delay_s(self, slo_class: str, now: float) -> float:
        """Predicted queueing delay for a request of ``slo_class`` arriving
        now: the serial-accelerator backlog plus how many service
        generations of the slot pool must drain before it starts, scaled
        by the observed (EWMA) per-request cloud service time.  With the
        priority queue on, an interactive request only waits behind
        interactive ones — exactly why batch absorbs the shed."""
        srv = self.server
        rank = 0 if (slo_class == "interactive" and self.policy.priority) \
            else 1
        q = srv.pending
        if isinstance(q, JobQueue) and self.policy.priority and rank == 0:
            ahead = sum(1 for e in q._entries.values() if e[0] <= rank)
        else:
            ahead = len(q)
        cap = max(len(srv.slots), 1)
        if slo_class == "batch":
            cap = max(cap - self.policy.reserved_slots, 1)
        free = sum(1 for s in srv.slots if s is None)
        waves = max(ahead + 1 - free, 0) / cap
        frontier = max(0.0, srv._prefill_busy_until - now)
        return frontier + waves * (self._svc_ewma or 0.0)

    def _shed(self, req, reason: str, now: float) -> None:
        t = req.trace
        t.outcome = "shed"
        t.failure = reason
        t.t_done = now
        t.clamp_chain()
        self.telemetry.counters["gateway_shed"] += 1
        self.telemetry.counters[f"gateway_shed_{t.slo_class}"] += 1
        self.telemetry.record(t)
        self.server.sim_request_done(req)

    def _serve_cached(self, req, ids: Tuple[int, ...], now: float) -> None:
        """Byte-identical reply from the LRU: the generated ids ship down
        the wire immediately; no slot, no accelerator time."""
        t = req.trace
        t.cache_hit = True
        t.new_tokens = len(ids)
        t.t_cloud_start = t.t_cloud_done = now
        req.cached_ids = ids
        req.state = "cloud"
        self.telemetry.counters["gateway_cache_hits"] += 1
        self.server._ship_ids(req)

    # -- hedged retries -----------------------------------------------------
    def wants_hedge(self, req) -> bool:
        return self.policy.hedge and \
            req.trace.slo_class == "interactive" and \
            req.max_new_tokens >= 1

    def arm_hedge(self, device, req) -> None:
        """Duplicate the payload send if the first is still stuck in the
        uplink phase after the hedge delay — racing loss/blackout, not the
        queue; the server's dedup drops whichever copy lands second."""
        def fire() -> None:
            if req.finished or req.state != "uplink":
                return
            req.trace.hedges += 1
            self.telemetry.counters["gateway_hedges"] += 1
            device.send_payload(req)
        self.loop.schedule(self.policy.hedge_delay_s, fire)

    # -- health/outcome signals ---------------------------------------------
    def note_outcome(self, req) -> None:
        """Terminal-request hook (every path funnels through
        ``sim_request_done``): feeds the breaker state machines, the
        service-time EWMA, and the response cache."""
        t = req.trace
        now = self.loop.now
        if t.outcome == "done":
            if t.t_cloud_done > t.t_cloud_start and not t.cache_hit:
                obs = t.t_cloud_done - t.t_cloud_start
                self._svc_ewma = obs if self._svc_ewma is None else \
                    0.8 * self._svc_ewma + 0.2 * obs
            if self.policy.breaker and not t.fallback:
                if self._breaker(t.cell).record_success(now):
                    self.telemetry.counters["gateway_breaker_closes"] += 1
                    self._poke(t.cell, now)
            if req.engine_req is not None and \
                    getattr(req.engine_req, "generated", None):
                self.cache.put(self.cache.key(req), req.engine_req.generated)
        elif t.outcome == "failed":
            self._note_failure(t.cell, now)

    def note_dropped_payload(self, cell: str) -> None:
        """Outage-dropped ingress: a health signal the breaker counts even
        though the request itself is still retrying."""
        self._note_failure(cell, self.loop.now)

    def _note_failure(self, cell: str, now: float) -> None:
        if self.policy.breaker and \
                self._breaker(cell).record_failure(now):
            self.telemetry.counters["gateway_breaker_opens"] += 1
            self._poke(cell, now)

    def _poke(self, cell: str, now: float) -> None:
        cb = self.pokes.get(cell)
        if cb is not None:
            cb(now, "breaker")

    # -- autoscaling --------------------------------------------------------
    def _autoscale_tick(self) -> None:
        now = self.loop.now
        srv = self.server
        p = self.policy
        load = srv.current_load(now)
        if load >= p.scale_up_load and self._target_replicas < p.max_replicas:
            self._target_replicas += 1
            self.telemetry.counters["gateway_scale_up_decisions"] += 1
            self.loop.schedule(p.spin_up_s, self._replica_up)
        elif load <= p.scale_down_load and self._target_replicas > 1 and \
                srv.replicas > 1:
            base = srv.max_concurrent
            if all(s is None for s in srv.slots[-base:]):
                del srv.slots[-base:]
                srv.replicas -= 1
                self._target_replicas -= 1
                self.telemetry.counters["gateway_scale_downs"] += 1

    def _replica_up(self) -> None:
        srv = self.server
        if srv.replicas >= self._target_replicas:
            return                       # a scale-down already retracted it
        srv.replicas += 1
        srv.slots.extend([None] * srv.max_concurrent)
        self.telemetry.counters["gateway_scale_ups"] += 1
        srv._kick()                      # fresh capacity: drain the queue
