"""Production mesh builders.

Kept as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init and then
calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
