"""repro: the butterfly-unit collaborative-intelligence framework in JAX.

Layers: configs (assigned archs), models (substrate), core (the paper's
contribution: butterfly + Algorithm 1), kernels (Pallas), data, training,
serving, launch (mesh/dryrun/roofline/CLIs).
"""
__version__ = "0.1.0"
