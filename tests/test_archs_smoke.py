"""Per-architecture smoke tests (assignment deliverable f): a REDUCED variant
of each assigned family (2 layers, d_model<=512, <=4 experts) runs one
forward and one train step on CPU; output shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.all import ASSIGNED
from repro.models import model as M
from repro.training import AdamWConfig, adamw_init, constant_schedule, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = {}
    if cfg.num_patches:
        batch["tokens"] = jax.random.randint(key, (B, S - cfg.num_patches), 0,
                                             cfg.vocab_size)
        batch["patches"] = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_frames,
                                                  cfg.d_model), jnp.dtype(cfg.dtype))
    batch["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = M.forward_train(params, built, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux["load_balance"]))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(built, AdamWConfig(lr=constant_schedule(1e-3))))
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg, jax.random.key(1)).items()}
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_butterfly_variant(arch):
    """The paper's technique applies to every assigned arch (DESIGN.md 4)."""
    cfg = get_config(arch).reduced().with_butterfly(layer=1, d_r=16)
    built = M.build(cfg)
    assert len(built.stages) == 2
    params, _ = M.init_model(jax.random.key(0), built)
    batch = _batch(cfg, jax.random.key(1))
    logits, _ = M.forward_train(params, built, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
