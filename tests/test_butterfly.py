"""The paper's contribution: butterfly unit semantics, compression accounting
(paper Sec III-D numbers reproduced exactly), stage splitting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.resnet50 import PAPER_MIN_DR, resnet50
from repro.core import butterfly as bf
from repro.core.quantization import dequantize
from repro.models import model as M


def test_compression_ratio_paper_rb1():
    """Paper Sec III-D: butterfly after RB1 compresses 256 -> 1 channels =
    256x ratio (8-bit wire vs 8-bit baseline features)."""
    assert bf.compression_ratio(d=256, d_r=1, act_bits=8, wire_bits=8) == 256.0


def test_paper_offloaded_bytes_table5():
    """Offloaded data sizes in Table V: 3136 B after RB1 (d_r=1, 56x56) and
    980~1000 B after RB8 (d_r=5, 14x14)."""
    cfg = resnet50()
    assert cfg.feature_bytes(1, bits=8, channels=1) == 3136
    assert cfg.feature_bytes(8, bits=8, channels=5) == 14 * 14 * 5  # 980
    # cloud-only input: 224*224*3 = 150528 (Table V)
    assert cfg.image_size ** 2 * 3 == 150528


def test_paper_min_dr_monotone_in_depth():
    """Fig. 7: deeper splits need larger D_r."""
    vals = [PAPER_MIN_DR[i] for i in range(1, 17)]
    assert vals == sorted(vals)
    assert vals[0] == 1 and vals[-1] == 10


def test_reduce_restore_units_roundtrip():
    key = jax.random.key(0)
    params, _ = bf.init_butterfly(key, d=64, bf=get_config("qwen3-8b")
                                  .reduced().with_butterfly(1, 16).butterfly,
                                  dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, 64))
    codes, scales = bf.reduce_unit(params, x)
    assert codes.dtype == jnp.int8 and codes.shape == (4, 8, 16)
    out = bf.restore_unit(params, codes, scales, jnp.float32)
    assert out.shape == x.shape
    # identical to the in-graph fake-quant form
    ref = bf.apply_butterfly(params, x, train=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_path_matches_reference_path():
    params = {
        "w_reduce": jax.random.normal(jax.random.key(2), (64, 16)) * 0.1,
        "w_restore": jax.random.normal(jax.random.key(3), (16, 64)) * 0.1,
    }
    x = jax.random.normal(jax.random.key(4), (2, 8, 64))
    c1, s1 = bf.reduce_unit(params, x, use_kernel=False)
    c2, s2 = bf.reduce_unit(params, x, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_stage_split_layer_counts():
    cfg = get_config("gemma3-12b").reduced().with_butterfly(layer=1, d_r=8)
    built = M.build(cfg)
    n0 = sum(s.num_layers for s in built.stages[0])
    n1 = sum(s.num_layers for s in built.stages[1])
    assert n0 == 1 and n0 + n1 == cfg.num_layers


@pytest.mark.parametrize("wire_bits", [4, 8, 16])
def test_butterfly_wire_bits(wire_bits):
    cfg = get_config("qwen3-8b").reduced().with_butterfly(1, 16, wire_bits)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits, _ = M.forward_train(params, built, {"tokens": toks})
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_int4_wire_accuracy_within_paper_bound():
    """The paper's D_r selection criterion (<2% accuracy loss) applied to the
    wire width: on a briefly-trained model, dropping the wire from int8 to
    int4 moves held-out next-token accuracy by less than 2 points."""
    import dataclasses
    from repro.data import lm_batches
    from repro.training import AdamWConfig, adamw_init, constant_schedule, \
        make_train_step

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=64)
    cfg = cfg.with_butterfly(layer=1, d_r=32)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(built, AdamWConfig(lr=constant_schedule(3e-3))))
    stream = iter(lm_batches(cfg.vocab_size, 32, 8, seed=7))
    for _, raw in zip(range(60), stream):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, _ = step(params, opt, batch)
    held_out = [jnp.asarray(next(stream)["tokens"]) for _ in range(4)]

    def accuracy(bits):
        c = dataclasses.replace(
            cfg, butterfly=dataclasses.replace(cfg.butterfly, wire_bits=bits))
        b = M.build(c)
        fwd = jax.jit(lambda p, t: M.forward_train(p, b, {"tokens": t})[0])
        hits = tot = 0
        for toks in held_out:
            pred = jnp.argmax(fwd(params, toks)[:, :-1], -1)
            hits += float((pred == toks[:, 1:]).sum())
            tot += pred.size
        return hits / tot

    acc8, acc4 = accuracy(8), accuracy(4)
    assert acc8 > 0.25, f"model failed to learn the chain ({acc8})"
    assert abs(acc8 - acc4) < 0.02, (acc8, acc4)


def test_butterfly_gradients_flow_to_both_stages():
    """End-to-end training through the wire: every stage gets gradient."""
    cfg = get_config("qwen3-8b").reduced().with_butterfly(layer=1, d_r=16)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)

    def loss(p):
        lg, _ = M.forward_train(p, built, {"tokens": toks})
        return M.lm_loss(lg[:, :-1], toks[:, 1:])

    g = jax.grad(loss)(params)
    for stage in (0, 1):
        norms = [float(jnp.sum(jnp.square(x)))
                 for x in jax.tree.leaves(g["stages"][stage])]
        assert sum(norms) > 0, f"stage {stage} got no gradient"
    assert float(jnp.sum(jnp.abs(g["butterfly"]["w_reduce"]))) > 0
    assert float(jnp.sum(jnp.abs(g["butterfly"]["w_restore"]))) > 0
