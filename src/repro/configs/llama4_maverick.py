"""llama4-maverick-400b-a17b [moe] — 128 experts, top-1 routing, one shared
expert, MoE on every other layer (interleave step 2), early-fusion multimodal
backbone (text path here). [hf:meta-llama/Llama-4-Scout-17B-16E family card]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("llama4-maverick-400b-a17b")
def llama4_maverick() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        arch_type="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,                    # dense-layer / shared-expert ffn width
        vocab_size=202048,
        act="silu",
        rope_theta=5e5,
        tie_embeddings=False,
        moe=MoEConfig(
            num_experts=128,
            top_k=1,
            d_ff_expert=8192,
            shared_expert_ff=8192,
            every=2,                  # MoE every other layer (maverick card)
        ),
        source="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick row: 128e top-1, interleaved MoE)",
    )
