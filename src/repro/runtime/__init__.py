"""Split-serving runtime: an event-driven edge/cloud request simulator.

The paper's headline numbers come from *deploying* the butterfly split under
request traffic and adapting the partition point to server load (Sec. III-C).
This package provides the missing request-stream layer on top of the repo's
static pieces:

  clock.py       deterministic discrete-event loop (reproducible traces)
  wire.py        contended uplink + downlink, windowed goodput feedback
  telemetry.py   per-request breakdown, p50/p95/p99, per-cell fairness
  tracing.py     flight recorder: virtual-clock spans -> Chrome trace JSON
  metrics.py     counters/gauges/histograms, fixed-interval sampler, and
                 opt-in wall-clock jit profiling
  split_exec.py  real jax numerics for the edge/cloud halves + cost model
  transports.py  pluggable decode transports (cache handoff vs streamed rows)
  actors.py      edge-device fleets and the cloud continuous-batching server
  controller.py  per-cell adaptive split + transport control (pluggable
                 objectives: latency / energy / energy_under_slo)
  simulator.py   multi-cell topologies (CellSpec grammar), arrival-trace
                 record/replay, and the runnable simulation

Entry points: ``repro.launch.runtime_sim`` (CLI) and
``benchmarks.run runtime`` (JSON comparison vs cloud-only offload).
"""
from repro.runtime.clock import EventLoop
from repro.runtime.controller import AdaptiveSplitController
from repro.runtime.metrics import (CountersView, JitProfiler, MetricsRegistry,
                                   MetricsSampler, read_metrics_jsonl)
from repro.runtime.simulator import (Arrival, CellSpec, SimConfig, Simulation,
                                     Topology, parse_topology,
                                     poisson_arrivals, record_arrivals,
                                     trace_arrivals)
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.tracing import (NULL_TRACER, Tracer, validate_chrome_trace)
from repro.runtime.transports import DecodeTransport, get_transport
from repro.runtime.wire import Wire

__all__ = ["EventLoop", "AdaptiveSplitController", "Arrival", "CellSpec",
           "SimConfig", "Simulation", "Topology", "RequestTrace", "Telemetry",
           "Wire", "DecodeTransport", "get_transport", "parse_topology",
           "poisson_arrivals", "record_arrivals", "trace_arrivals",
           "Tracer", "NULL_TRACER", "validate_chrome_trace",
           "MetricsRegistry", "MetricsSampler", "CountersView", "JitProfiler",
           "read_metrics_jsonl"]
