"""qwen3-8b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig, register


@register("qwen3-8b")
def qwen3_8b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        arch_type="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        act="silu",
        rope_theta=1e6,
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-8B",
    )
