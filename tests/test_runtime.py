"""Split-serving runtime: deterministic scheduler, uplink contention math,
slot reuse, adaptive split control, and end-to-end numerics (the split path
must reproduce the single-mesh forward up to f32 rounding, and the emitted
greedy tokens exactly)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.planner import select_split_online, wire_mode_bytes
from repro.core.profiler import JETSON_TX2
from repro.core.wireless import NETWORKS, get_link
from repro.runtime.clock import EventLoop
from repro.runtime.simulator import SimConfig, Simulation, ramp_load
from repro.runtime.telemetry import percentile
from repro.runtime.wire import Wire


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------


def test_event_loop_orders_by_time_then_fifo():
    loop = EventLoop()
    order = []
    loop.schedule_at(2.0, lambda: order.append("late"))
    loop.schedule_at(1.0, lambda: order.append("a"))
    loop.schedule_at(1.0, lambda: order.append("b"))     # tie: FIFO
    loop.schedule_at(0.5, lambda: order.append("first"))
    loop.run()
    assert order == ["first", "a", "b", "late"]
    assert loop.now == 2.0


def test_event_loop_rejects_past_and_nested_schedules_run():
    loop = EventLoop()
    seen = []
    loop.schedule_at(1.0, lambda: loop.schedule(0.5, lambda: seen.append(2)))
    loop.schedule_at(1.2, lambda: seen.append(1))
    loop.run()
    assert seen == [1, 2]
    with pytest.raises(ValueError):
        loop.schedule_at(0.0, lambda: None)              # now == 1.5


# ---------------------------------------------------------------------------
# wire / contention
# ---------------------------------------------------------------------------


def test_uplink_contention_serializes_transfers():
    net = NETWORKS["3g"]
    up = Wire(net)
    nbytes = 11_000                       # 11kB over 1.1Mbps = 80ms
    dur = net.uplink_seconds(nbytes)
    s1, d1 = up.transfer(nbytes, 0.0)
    s2, d2 = up.transfer(nbytes, 0.0)     # same instant: must queue
    s3, d3 = up.transfer(nbytes, d2)      # after drain: immediate
    assert (s1, d1) == (0.0, pytest.approx(dur))
    assert s2 == pytest.approx(d1) and d2 == pytest.approx(2 * dur)
    assert s3 == pytest.approx(d2) and d3 == pytest.approx(3 * dur)
    assert up.stats.wait_s == pytest.approx(dur)          # only transfer 2
    assert up.stats.busy_s == pytest.approx(3 * dur)
    assert up.stats.bytes_sent == 3 * nbytes
    # goodput includes the queueing: 3B over (3*dur busy + dur wait)
    assert up.stats.energy_mj == pytest.approx(
        3 * net.uplink_energy_mj(nbytes))
    assert up.observed_bytes_per_s(d3) == pytest.approx(
        3 * nbytes / (4 * dur))


def test_get_link_names():
    assert get_link("3g").uplink_mbps == 1.1
    assert get_link("inter_pod").uplink_seconds(50e9) == pytest.approx(1.0)
    with pytest.raises(KeyError):
        get_link("5g")


def test_percentile_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 0) == 1.0
    assert percentile([7.0], 99) == 7.0


# ---------------------------------------------------------------------------
# scheduler semantics (timing-only mode)
# ---------------------------------------------------------------------------


def test_traces_complete_and_breakdown_sums():
    sim = Simulation(timing_cfg(max_new_tokens=4))
    tel = sim.run()
    assert len(tel.traces) == 16
    for t in tel.traces:
        parts = sum(t.breakdown().values())
        assert parts == pytest.approx(t.latency_s, abs=1e-12)
        assert t.t_arrival <= t.t_edge_start <= t.t_edge_done \
            <= t.t_uplink_start <= t.t_uplink_done <= t.t_cloud_start \
            <= t.t_first_token <= t.t_done


def test_deterministic_replay():
    t1 = Simulation(timing_cfg(max_new_tokens=3)).run()
    t2 = Simulation(timing_cfg(max_new_tokens=3)).run()
    a = [(t.uid, t.t_arrival, t.t_done, t.wire_bytes) for t in t1.traces]
    b = [(t.uid, t.t_arrival, t.t_done, t.wire_bytes) for t in t2.traces]
    assert a == b


def test_cloud_slots_bounded_and_reused():
    # instant wire + congested cloud: payloads pile up against 2 slots
    sc = timing_cfg(network="inter_pod", num_devices=8, num_requests=24,
                    arrival_rate=500.0, max_new_tokens=8, max_concurrent=2,
                    background_load=lambda t: 0.9)
    sim = Simulation(sc)
    tel = sim.run()
    assert len(tel.traces) == 24
    assert sim.server.peak_active <= 2
    slots_used = {s for _, s in sim.server.slot_history}
    assert slots_used == {0, 1}                       # both slots exercised
    reuse_counts = [sum(1 for _, s in sim.server.slot_history if s == k)
                    for k in (0, 1)]
    assert all(c >= 2 for c in reuse_counts)          # ... more than once
    assert len(sim.server.slot_history) == 24


def test_device_queue_is_serial():
    # one device, instantaneous uplink contention aside: edge starts are
    # spaced by at least the edge compute duration
    sc = timing_cfg(num_devices=1, num_requests=8, arrival_rate=1e5)
    tel = Simulation(sc).run()
    ts = sorted((t.t_edge_start, t.t_edge_done) for t in tel.traces)
    for (s0, d0), (s1, _) in zip(ts, ts[1:]):
        assert s1 >= d0 - 1e-15


# ---------------------------------------------------------------------------
# the paper's comparisons
# ---------------------------------------------------------------------------


def test_int8_wire_beats_raw_offload_on_3g():
    int8 = Simulation(timing_cfg(wire_mode="int8")).run().summary()
    raw = Simulation(timing_cfg(wire_mode="raw")).run().summary()
    cloud = Simulation(timing_cfg(mode="cloud")).run().summary()
    assert int8["latency_p50_ms"] < raw["latency_p50_ms"] / 10
    assert int8["latency_p50_ms"] < cloud["latency_p50_ms"] / 10
    assert int8["mean_mobile_energy_mj"] < cloud["mean_mobile_energy_mj"]
    assert int8["mean_wire_kb"] < cloud["mean_wire_kb"] / 10


def test_wire_mode_bytes_ordering():
    cfg = small_cfg()
    raw = wire_mode_bytes(cfg, 32, 16, "raw")
    red = wire_mode_bytes(cfg, 32, 16, "reduced")
    q = wire_mode_bytes(cfg, 32, 16, "int8")
    assert q < red < raw
    assert q == 32 * 16 + 32 * 4                      # codes + f32 scales


# ---------------------------------------------------------------------------
# adaptive split control
# ---------------------------------------------------------------------------


def test_online_selection_moves_deeper_with_load():
    cfg = small_cfg()
    edge = JETSON_TX2
    cloud = edge.scaled(10)
    link = NETWORKS["3g"].uplink_mbps * 1e6 / 8
    picks = []
    for load in (0.0, 0.5, 0.89, 0.95, 0.975):
        best, rows = select_split_online(
            cfg, 32, 16, candidate_splits=[1, 2, 3], edge=edge, cloud=cloud,
            link_bytes_per_s=link, cloud_load=load)
        picks.append(best["split"])
        assert len(rows) == 3
    assert picks[0] == 1                              # idle cloud: shallow
    assert picks == sorted(picks)                     # monotone in load
    assert picks[-1] == 3                             # congested: deep


def test_controller_moves_split_past_090():
    sc = timing_cfg(num_requests=64, arrival_rate=40.0, adapt=True,
                    control_interval_s=0.02,
                    cloud=JETSON_TX2.scaled(10, "cloud_slice"),
                    background_load=ramp_load(0.0, 0.25, 0.0, 0.97))
    tel = Simulation(sc).run()
    assert tel.decisions, "controller never ran"
    low = [d.new_split for d in tel.decisions if d.cloud_load < 0.5]
    high = [d.new_split for d in tel.decisions if d.cloud_load > 0.93]
    assert low and high
    assert max(low) < min(high)                       # strictly deeper
    # and requests admitted after the move actually carry the deeper split
    deep = {t.split for t in tel.traces if t.t_arrival > 0.3}
    assert deep and min(deep) > 1


# ---------------------------------------------------------------------------
# end-to-end numerics (real jax)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def numerics_sim():
    sc = SimConfig(cfg=small_cfg(layers=2), mode="split", wire_mode="int8",
                   network="3g", num_devices=2, num_requests=4,
                   arrival_rate=20.0, prompt_len=16, max_new_tokens=3,
                   d_r=16, numerics=True, max_concurrent=2, seed=0)
    sim = Simulation(sc)
    tel = sim.run()
    return sim, tel


def test_e2e_split_prefill_matches_reference(numerics_sim):
    import jax.numpy as jnp
    sim, tel = numerics_sim
    runner = sim.bank.runner(1)
    for req in sim.requests:
        payload, scales, _ = runner.edge_half(runner.params,
                                              req.tokens[None])
        logits, _ = runner.cloud_half(runner.params, payload, scales)
        ref, _ = runner.reference_prefill(req.tokens[None])
        # jit (split halves) vs eager (reference) differ only in f32 rounding
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, -1]),
                                   rtol=1e-4, atol=1e-4)
        # the token the runtime actually emitted == greedy argmax of the
        # reference single-mesh forward
        assert req.engine_req.generated[0] == int(jnp.argmax(ref[0, -1]))


def test_bank_holds_one_backbone_param_copy():
    """Materializing every candidate split must not copy the backbone: each
    runner's param dict shares the bank's leaves by identity, and the unique
    parameter bytes across all runners stay within the tiny per-split
    butterfly overhead of a single model's footprint."""
    import jax
    from repro.runtime.split_exec import SplitModelBank

    bank = SplitModelBank(small_cfg(layers=4), 16, seed=0)
    runners = [bank.runner(s) for s in bank.candidates]
    assert len(runners) == 3

    backbone_ids = {id(l) for l in jax.tree.leaves(bank.params)}
    backbone_bytes = sum(l.nbytes for l in jax.tree.leaves(bank.params))
    seen, total = set(), 0
    for r in runners:
        # the stages/embed/norm subtrees ARE the bank's objects, not copies
        assert r.params["stages"] is bank.params["stages"]
        assert r.params["embed"] is bank.params["embed"]
        for leaf in jax.tree.leaves(r.params):
            if id(leaf) not in seen:
                seen.add(id(leaf))
                total += leaf.nbytes
    butterfly_bytes = total - backbone_bytes
    assert backbone_ids <= seen
    # 3 splits x (d*d_r + d_r*d) f32 — well under 10% of one backbone
    assert butterfly_bytes < 0.1 * backbone_bytes
    d = bank.base_cfg.d_model
    assert butterfly_bytes == 3 * 2 * d * 16 * 4


def test_cache_injection_parity_all_wire_modes():
    """Edge half -> wire -> cloud half -> submit_prefilled must reproduce
    the single-mesh reference forward (logits) and the engine's own
    full-prefill decode (tokens) for every wire mode."""
    import jax.numpy as jnp
    from repro.runtime.split_exec import SplitModelBank

    rng = np.random.default_rng(7)
    toks = rng.integers(0, 512, size=(1, 16)).astype(np.int32)
    for wm in ("raw", "reduced", "int8"):
        bank = SplitModelBank(small_cfg(layers=2), 16, wire_mode=wm, seed=0)
        r = bank.runner(1)
        payload, scales, c0 = r.edge_half(r.params, toks)
        logits, c1 = r.cloud_half(r.params, payload, scales)
        ref, _ = r.reference_prefill(toks)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, -1]),
                                   rtol=1e-4, atol=1e-4, err_msg=wm)
        # inject the handed-off caches and decode greedily ...
        eng = r.make_engine(max_batch=2, max_len=24, seed=0)
        inj = eng.submit_prefilled(16, [c0, c1], logits[0], max_new_tokens=4)
        eng.run()
        # ... and compare against the same engine prefilling from scratch
        ref_req = eng.submit(toks[0], max_new_tokens=4)
        eng.run()
        assert inj.generated[0] == int(jnp.argmax(ref[0, -1])), wm
        assert inj.generated == ref_req.generated, wm


def test_bank_unaligned_boundary_peels_units():
    """xLSTM alternates mlstm/slstm in 2-layer repeat units, so odd splits
    land inside a unit: the range view must peel only the unaligned edges
    (keeping the stacked middle) and still match the reference forward.
    Recurrent state also disables seq bucketing — shapes stay exact."""
    from repro.models.transformer import range_segments
    from repro.runtime.split_exec import SplitModelBank

    cfg = dataclasses.replace(get_config("xlstm-125m").reduced(),
                              num_layers=4)
    bank = SplitModelBank(cfg, 16, seed=0)
    assert not bank._seq_bucket_ok
    segs = list(bank.built.stages[0])
    assert [(len(s.unit), s.repeats) for s in segs] == [(2, 2)]
    # split 1: peel layer 0 | peel layer 1 + slice repeats [1, 2)
    assert [(len(s.unit), s.repeats)
            for s in range_segments(segs, 0, 1)] == [(1, 1)]
    assert [(len(s.unit), s.repeats)
            for s in range_segments(segs, 1, 4)] == [(1, 1), (2, 1)]

    toks = np.random.default_rng(3).integers(
        0, cfg.vocab_size, size=(1, 12)).astype(np.int32)
    for split in bank.candidates:
        r = bank.runner(split)
        payload, scales, c0 = r.edge_half(r.params, toks)
        logits, c1 = r.cloud_half(r.params, payload, scales)
        ref, _ = r.reference_prefill(toks)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, -1]),
                                   rtol=1e-4, atol=1e-4, err_msg=str(split))


def test_submit_prefilled_boundary_prompt_len():
    """prompt_len == max_len - 1 is admissible: the first decode step writes
    the cache's last row, then the position guard retires the request."""
    from repro.runtime.split_exec import SplitModelBank

    bank = SplitModelBank(small_cfg(layers=2), 16, seed=0)
    r = bank.runner(1)
    toks = np.arange(16, dtype=np.int32)[None]
    payload, scales, c0 = r.edge_half(r.params, toks)
    logits, c1 = r.cloud_half(r.params, payload, scales)
    eng = r.make_engine(max_batch=1, max_len=17, seed=0)   # prompt_len + 1
    req = eng.submit_prefilled(16, [c0, c1], logits[0], max_new_tokens=8)
    eng.run()
    assert req.done
    assert len(req.generated) == 2          # first token + one decode step
    with pytest.raises(AssertionError):
        eng.submit_prefilled(17, [c0, c1], logits[0])      # == max_len


def test_engines_share_compiled_decode_step(numerics_sim):
    """Every engine of one bank split reuses the same jitted decode+sample
    step (the bank's compile cache, not a per-engine jit)."""
    sim, tel = numerics_sim
    r = sim.bank.runner(1)
    e1 = r.make_engine(max_batch=2, max_len=32)
    e2 = r.make_engine(max_batch=4, max_len=32)
    assert e1._step is e2._step
    assert tel.counters["engine_decode_steps"] > 0
    assert tel.counters["bank_jit_cache_entries"] > 0


def test_e2e_decode_runs_and_traces_close(numerics_sim):
    sim, tel = numerics_sim
    assert len(tel.traces) == 4
    for t in tel.traces:
        assert t.new_tokens == 3
        assert t.wire_bytes > 0
        assert sum(t.breakdown().values()) == pytest.approx(t.latency_s,
                                                            abs=1e-12)
    for req in sim.requests:
        assert req.engine_req.done
        assert len(req.engine_req.generated) == 3
    # every engine drained its slots (they were reused, not leaked)
    for eng in sim.server._engines.values():
        assert eng.num_active == 0
