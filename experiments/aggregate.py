"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python experiments/aggregate.py [--dir experiments/dryrun]
Prints: the section-Dry-run table, the section-Roofline table (single-pod),
the multi-pod compile-proof matrix, and — when experiments/BENCH_runtime.json
exists (written by ``benchmarks.run runtime``, or ingested from its CSV
output via ``--ingest-runtime <csv>``) — the split-serving runtime table
plus the cross-run perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import math
import os
from collections import defaultdict

# Telemetry schema versions this aggregator understands.  Mirrors
# repro.runtime.telemetry.SCHEMA_VERSION (duplicated on purpose: the CI
# runtime-table job runs this script without PYTHONPATH=src, so it must not
# import repro; tests/test_observability.py cross-checks the two stay in
# sync).  None covers trajectory runs recorded before the field existed.
KNOWN_SCHEMA_VERSIONS = (None, 2, 3, 4, 5)

ARCH_ORDER = ["qwen3-14b", "llama4-maverick-400b-a17b", "qwen3-moe-235b-a22b",
              "pixtral-12b", "whisper-base", "gemma-7b", "gemma3-12b",
              "qwen3-8b", "xlstm-125m", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    recs = {}
    if not os.path.isdir(d):
        return recs
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, f)))
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        tag = f.rsplit("_", 1)[-1].replace(".json", "")
        recs.setdefault(key, []).append((f, r))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB" if b > 1e9 else f"{b/1e6:.1f}MB"


RUNTIME_JSON = os.path.join(os.path.dirname(__file__), "BENCH_runtime.json")


def append_runs(results, out_path: str = RUNTIME_JSON) -> None:
    """Append runtime-benchmark result docs to the BENCH_runtime.json
    trajectory (the one writer — ``benchmarks.run runtime`` calls this
    too).  A corrupt or schema-less existing file starts a fresh doc."""
    doc = {"benchmark": "benchmarks.run runtime", "runs": []}
    if os.path.exists(out_path):
        try:
            loaded = json.load(open(out_path))
            if isinstance(loaded.get("runs"), list):
                doc = loaded
        except (ValueError, OSError):
            pass
    for result in results:
        doc["runs"].append(dict(result, run=len(doc["runs"])))
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def ingest_runtime(csv_path: str, out_path: str = RUNTIME_JSON) -> int:
    """Parse ``runtime/json`` rows out of a ``benchmarks.run runtime`` CSV
    capture and append them to the BENCH_runtime.json trajectory."""
    results = [json.loads(line.split(",", 2)[2])
               for line in open(csv_path)
               if line.startswith("runtime/json,")]
    if results:
        append_runs(results, out_path)
    return len(results)


# ---------------------------------------------------------------- ratchet
# Metric direction is inferred from the leaf key name; keys matching
# neither list (counts, split indices, workload echo) are not ratcheted.
LOWER_IS_BETTER = ("latency", "ttft", "_ms", "_kb", "rtt")
HIGHER_IS_BETTER = ("speedup", "throughput", "reduction", "goodput")
RATCHET_THRESHOLD = 0.15


def _finite(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def _direction(key: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 not a ratcheted metric."""
    leaf = key.rsplit(".", 1)[-1].lower()
    if any(tok in leaf for tok in HIGHER_IS_BETTER):
        return 1
    if any(tok in leaf for tok in LOWER_IS_BETTER):
        return -1
    return 0


def _flatten(doc, prefix=""):
    out = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif _finite(doc):
        out[prefix[:-1]] = float(doc)
    return out


def check_regression(fresh, baseline_runs, threshold: float = RATCHET_THRESHOLD):
    """Ratchet a fresh runtime-benchmark run against the trajectory.

    For every direction-inferred metric present in both ``fresh`` and at
    least one baseline run, the baseline is the *best* value over the whole
    trajectory (min for lower-is-better, max for higher-is-better) — the
    ratchet only ever tightens.  A metric violates when it is more than
    ``threshold`` (relative) worse than that best.  Baseline runs that are
    content-equal to ``fresh`` (ignoring the ``run`` counter) are excluded,
    because ``benchmarks.run runtime`` appends the fresh run to
    BENCH_runtime.json in place before the check executes.

    Returns ``{"checked", "baseline_runs", "violations": [...]}`` where each
    violation is ``{"key", "fresh", "best", "best_run", "drift"}``.
    """
    fresh_body = {k: v for k, v in fresh.items() if k != "run"}
    baselines = [r for r in baseline_runs
                 if {k: v for k, v in r.items() if k != "run"} != fresh_body]
    flat_baselines = [(r.get("run"), _flatten(r)) for r in baselines]
    violations, checked = [], 0
    for key, value in sorted(_flatten(fresh).items()):
        d = _direction(key)
        if d == 0:
            continue
        best, best_run = None, None
        for run_id, flat in flat_baselines:
            v = flat.get(key)
            if v is None or not math.isfinite(v) or v <= 0:
                continue
            if best is None or (v < best if d < 0 else v > best):
                best, best_run = v, run_id
        if best is None:
            continue  # metric new to the trajectory: nothing to ratchet
        checked += 1
        drift = (value - best) / best if d < 0 else (best - value) / best
        if drift > threshold:
            violations.append({"key": key, "fresh": value, "best": best,
                               "best_run": best_run,
                               "drift": round(drift, 4)})
    return {"checked": checked, "baseline_runs": len(baselines),
            "violations": violations}


def _load_fresh_run(spec: str, traj_path: str = RUNTIME_JSON):
    """Resolve --check-regression's argument into (fresh_run, baselines).

    ``spec`` may be: '' (compare the trajectory's last run against the
    earlier ones), a ``benchmarks.run runtime`` CSV capture (rows prefixed
    ``runtime/json,``), or a JSON file (a single run doc, or a
    ``{"runs": [...]}`` trajectory whose last run is the candidate).
    """
    doc = json.load(open(traj_path)) if os.path.exists(traj_path) else {}
    trajectory = doc.get("runs", [])
    if not spec:
        if len(trajectory) < 2:
            raise SystemExit(f"{traj_path} needs >=2 runs to ratchet the "
                             f"last against the rest")
        return trajectory[-1], trajectory[:-1]
    if not os.path.exists(spec):
        raise SystemExit(f"--check-regression: {spec} not found")
    text = open(spec).read()
    csv_rows = [json.loads(line.split(",", 2)[2])
                for line in text.splitlines()
                if line.startswith("runtime/json,")]
    if csv_rows:
        return csv_rows[-1], trajectory
    loaded = json.loads(text)
    if isinstance(loaded.get("runs"), list) and loaded["runs"]:
        return loaded["runs"][-1], trajectory or loaded["runs"][:-1]
    return loaded, trajectory


def run_check(spec: str, threshold: float = RATCHET_THRESHOLD) -> None:
    fresh, baselines = _load_fresh_run(spec)
    sv = fresh.get("schema_version")
    if sv not in KNOWN_SCHEMA_VERSIONS:
        raise SystemExit(f"unknown telemetry schema_version {sv!r} "
                         f"(known: {KNOWN_SCHEMA_VERSIONS}); teach "
                         f"experiments/aggregate.py about it first")
    if not baselines:
        raise SystemExit("no baseline runs in BENCH_runtime.json to "
                         "ratchet against")
    report = check_regression(fresh, baselines, threshold)
    print(f"perf ratchet: {report['checked']} metrics vs best of "
          f"{report['baseline_runs']} baseline run(s), "
          f"threshold {threshold:.0%}")
    if report["violations"]:
        for v in report["violations"]:
            print(f"  REGRESSION {v['key']}: {v['fresh']:.4g} vs best "
                  f"{v['best']:.4g} (run {v['best_run']}), "
                  f"{v['drift']:+.1%} worse")
        raise SystemExit(f"{len(report['violations'])} metric(s) drifted "
                         f">{threshold:.0%} past the trajectory best")
    print("  OK — no metric worse than trajectory best by "
          f">{threshold:.0%}")


def print_runtime(path: str = RUNTIME_JSON, require: bool = False):
    """Render the split-serving runtime table from the checked-in
    trajectory.  ``require=True`` (the CI render step) fails loudly when the
    file is missing/empty instead of silently printing nothing — and any
    schema drift from new telemetry fields surfaces as a KeyError here."""
    if not os.path.exists(path):
        if require:
            raise SystemExit(f"{path} missing: runtime table cannot render")
        return
    doc = json.load(open(path))
    runs = doc.get("runs", [])
    if not runs:
        if require:
            raise SystemExit(f"{path} has no runs: nothing to render")
        return
    last = runs[-1]
    w = last.get("workload", {})
    print(f"\n### Split-serving runtime (run {last.get('run', len(runs)-1)}: "
          f"{w.get('arch', '?')}, {w.get('layers', '?')}L, "
          f"{w.get('requests', '?')} requests, d_r={w.get('d_r', '?')})\n")
    print("| network | cloud-only p50 | split int8 p50 | speedup "
          "| split wire/req | cloud wire/req |")
    print("|---|---|---|---|---|---|")
    for net in ("3g", "4g", "wifi"):
        row = last.get("networks", {}).get(net)
        if row is None:
            continue
        print(f"| {net} | {row['cloud_only']['latency_p50_ms']:.2f}ms "
              f"| {row['split_int8']['latency_p50_ms']:.2f}ms "
              f"| {row['split_speedup_vs_cloud']:.1f}x "
              f"| {row['split_int8']['mean_wire_kb']:.2f}kB "
              f"| {row['cloud_only']['mean_wire_kb']:.2f}kB |")
    tr = last.get("transports", {})
    if tr:
        w = tr.get("workload", {})
        print(f"\n#### Decode transports (S={w.get('prompt_len', '?')}, "
              f"T={w.get('max_new_tokens', '?')}, "
              f"{w.get('network', '?')}, identical arrival trace)\n")
        print("| transport | uplink/req | downlink/req | ttft p50 | p50 |")
        print("|---|---|---|---|---|")
        for tp in ("cache_handoff", "streamed"):
            row = tr.get(tp)
            if row is None:
                continue
            print(f"| {tp} | {row['mean_uplink_kb']:.2f}kB "
                  f"| {row['mean_downlink_b']:.0f}B "
                  f"| {row['ttft_p50_ms']:.2f}ms "
                  f"| {row['latency_p50_ms']:.2f}ms |")
        red = tr.get("streamed_uplink_reduction")
        if red is not None:
            print(f"\nstreamed ships {red}x fewer uplink bytes than the "
                  f"stage-0 cache handoff on this workload")
    ad = last.get("adaptive", {})
    if ad:
        print(f"\nadaptive: split {ad.get('split_at_low_load')} -> "
              f"{ad.get('split_at_high_load')} under the load ramp "
              f"(moved deeper past 0.9: {ad.get('moved_deeper_past_0.9')})")
    topo = last.get("topology")
    if topo:
        print(f"\n#### Multi-cell topology ({topo['spec']})\n")
        print("| cell | p50 | uplink wait | energy/req | final split "
              "| transport |")
        print("|---|---|---|---|---|---|")
        for name, row in sorted(topo["cells"].items()):
            print(f"| {name} | {row['latency_p50_ms']:.2f}ms "
                  f"| {row['mean_uplink_wait_ms']:.2f}ms "
                  f"| {row['mean_mobile_energy_mj']:.1f}mJ "
                  f"| {row['final_split']} | {row['final_transport']} |")
        fair = topo["fairness"]
        print(f"\nper-cell controllers diverged: "
              f"{topo['controllers_diverged']}; fairness max/min "
              f"{fair['max_min_latency_ratio']:.2f}x, p95 spread "
              f"{fair['p95_spread_ms']:.2f}ms, Jain {fair['jain_index']:.3f}")
        shared = topo["shared_3g_wire"]
        print(f"same fleet through ONE shared 3g wire: p50 "
              f"{shared['latency_p50_ms']:.2f}ms (Jain "
              f"{shared['fairness_jain']:.3f}) — "
              f"{topo['isolated_vs_shared_p50_speedup']}x slower than "
              f"per-cell radios")
    res = last.get("resilience")
    if res:
        print(f"\n#### Resilience (same topology under a chaos fault "
              f"schedule)\n")
        print(f"faults: {res['faults']}")
        print(f"availability {res['availability_pct']:.1f}% "
              f"({res['n_failed']} failed), p99 "
              f"{res['latency_p99_ms']:.2f}ms vs calm "
              f"{res['baseline_p99_ms']:.2f}ms; "
              f"{res['n_migrated']} migrated, {res['n_retried']} retried, "
              f"{res['n_edge_fallback']} edge fallbacks")
    gw = last.get("gateway")
    if gw:
        w = gw["workload"]
        print(f"\n#### Gateway (SLO-classed shedding under a "
              f"{w['n']//1000}k-request flash crowd)\n")
        print(f"workload: {w['kind']} rate={w['rate']}/dev "
              f"alpha={w['alpha']} burst={w['burst']}x over "
              f"[{w['at']}, {w['at'] + w['dur']})s, "
              f"{w['interactive']:.0%} interactive; "
              f"policy: {w['policy']}")
        print(f"interactive p99 {gw['interactive_p99_on_ms']:.1f}ms with "
              f"shedding vs {gw['interactive_p99_off_ms']:.1f}ms without "
              f"({gw['shed_interactive_p99_speedup']}x); "
              f"{gw['n_shed']} shed, all batch "
              f"({gw['n_shed_interactive']} interactive)")
    wire = last.get("wire")
    if wire:
        codec = wire.get("codec", {})
        w = wire.get("workload", {})
        print(f"\n#### Entropy-coded wire (schema v5: trained prior, "
              f"d_r={codec.get('d_r', '?')})\n")
        print(f"codec: {codec.get('entropy_bytes_per_token', float('nan')):.2f}"
              f"B/token entropy vs "
              f"{codec.get('int8_bytes_per_token', float('nan')):.2f}B/token "
              f"int8 ({codec.get('entropy_bytes_reduction', '?')}x fewer "
              f"bytes) at {codec.get('eval_loss_delta_pct', float('nan')):.2f}"
              f"% eval-loss delta")
        print(f"\n| wire mode | uplink/req | ttft p50 | p50 | compression |")
        print("|---|---|---|---|---|")
        for mode in ("int8", "int4", "entropy", "entropy_progressive"):
            row = wire.get("modes", {}).get(mode)
            if row is None:
                continue
            ratio = row.get("compression_ratio")
            ratio_s = f"{ratio:.2f}x" if _finite(ratio) else "-"
            print(f"| {mode} | {row['mean_wire_kb']:.2f}kB "
                  f"| {row['ttft_p50_ms']:.2f}ms "
                  f"| {row['latency_p50_ms']:.2f}ms | {ratio_s} |")
        spd = wire.get("progressive_ttft_p50_speedup")
        if spd is not None:
            print(f"\nprogressive upload/prefill overlap: {spd}x faster ttft "
                  f"p50 than non-progressive entropy on the "
                  f"{w.get('network', '?')} long-prompt trace "
                  f"(S={w.get('prompt_len', '?')}, "
                  f"T={w.get('max_new_tokens', '?')})")
    if len(runs) > 1:
        print("\n#### Perf trajectory (split int8 on 3g, per run)\n")
        for r in runs:
            row = r.get("networks", {}).get("3g", {})
            p50 = row.get("split_int8", {}).get("latency_p50_ms")
            spd = row.get("split_speedup_vs_cloud")
            thr = row.get("split_int8", {}).get("throughput_rps")
            # throughput is NaN for single-arrival spans — render as absent
            # rather than poisoning the table
            thr_note = f", {thr:.1f} req/s" if _finite(thr) else ""
            print(f"run {r.get('run', '?')}: {p50}ms "
                  f"({spd}x vs cloud-only{thr_note})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--ingest-runtime", metavar="CSV",
                    help="append runtime/json rows from a benchmarks.run "
                         "runtime CSV capture to BENCH_runtime.json")
    ap.add_argument("--runtime-only", action="store_true",
                    help="render ONLY the runtime table from the checked-in "
                         "BENCH_runtime.json, failing if it cannot render "
                         "(the CI artifact step: catches schema drift from "
                         "new telemetry fields)")
    ap.add_argument("--check-regression", nargs="?", const="",
                    metavar="CSV|JSON",
                    help="perf ratchet: compare a fresh benchmarks.run "
                         "runtime result (CSV capture with runtime/json "
                         "rows, or a JSON run doc/trajectory; no argument = "
                         "last checked-in run) against the best of the "
                         "BENCH_runtime.json trajectory; exit 1 on any "
                         "metric >threshold worse")
    ap.add_argument("--threshold", type=float, default=RATCHET_THRESHOLD,
                    help="relative drift tolerance for --check-regression")
    args = ap.parse_args()
    if args.ingest_runtime:
        n = ingest_runtime(args.ingest_runtime)
        print(f"ingested {n} runtime run(s) into {RUNTIME_JSON}")
    if args.check_regression is not None:
        run_check(args.check_regression, args.threshold)
        return
    if args.runtime_only:
        print_runtime(require=True)
        return
    recs = load(args.dir)

    def get(arch, shape, mesh):
        lst = recs.get((arch, shape, mesh), [])
        # prefer untagged baseline files
        for f, r in lst:
            if f == f"{arch}_{shape}_{mesh.replace('x','-')}.json":
                return r
        return lst[0][1] if lst else None

    print("### Dry-run matrix (compile status, peak device memory)\n")
    print("| arch | shape | 16x16 | 2x16x16 |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cells = []
            for mesh in ("16x16", "2x16x16"):
                r = get(a, s, mesh)
                if r is None:
                    cells.append("(missing)")
                elif "skipped" in r:
                    cells.append("skip (documented)")
                elif "error" in r:
                    cells.append("ERROR")
                else:
                    peak = r.get("memory_analysis", {}).get("peak_memory_in_bytes")
                    if peak is None:
                        peak = (r.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
                                + r.get("memory_analysis", {}).get("argument_size_in_bytes", 0))
                    cells.append(f"OK {fmt_bytes(peak)} ({r['compile_s']:.0f}s)")
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod 16x16, per-device terms, seconds)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| MODEL_FLOPS/HLO_FLOPS | collectives |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = get(a, s, "16x16")
            if not r or "compute_s" not in r:
                continue
            coll = ", ".join(f"{k.split('-')[-1] if False else k}={fmt_bytes(v)}"
                             for k, v in sorted(r.get("collectives", {}).items())
                             if v)
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                  f"| {r['collective_s']:.4f} | {r['bottleneck']} "
                  f"| {r['useful_ratio']:.2f} | {coll or '-'} |")

    missing = []
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = get(a, s, mesh)
                if r is None or "error" in r:
                    missing.append((a, s, mesh))
    n_ok = sum(1 for lst in recs.values() for f, r in lst if "compute_s" in r)
    print(f"\nartifacts: {n_ok} compiled records; outstanding: {missing if missing else 'none'}")
    print_runtime()


if __name__ == "__main__":
    main()
