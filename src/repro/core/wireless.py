"""Wireless network + power models from the paper (Tables III), used by the
faithful reproduction benchmarks, plus the TPU interconnect profile used by
the deployment planner.

Paper's uplink power model (Huang et al., MobiSys'12): P_u = alpha_u * t_u + beta
with t_u the uplink throughput in Mbps and P in mW.  The same source gives the
downlink coefficients (P_d = alpha_d * t_d + beta), which the split runtime's
streamed decode transport uses to charge the mobile for receiving one sampled
token per generation step.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class WirelessNetwork:
    name: str
    uplink_mbps: float
    alpha_mw_per_mbps: float
    beta_mw: float
    # downlink side; 0.0 falls back to the uplink figures (symmetric link)
    downlink_mbps: float = 0.0
    alpha_d_mw_per_mbps: float = 0.0

    def uplink_seconds(self, nbytes: float) -> float:
        return nbytes * 8.0 / (self.uplink_mbps * 1e6)

    def uplink_power_mw(self) -> float:
        return self.alpha_mw_per_mbps * self.uplink_mbps + self.beta_mw

    def uplink_energy_mj(self, nbytes: float) -> float:
        return self.uplink_seconds(nbytes) * 1e3 * self.uplink_power_mw() * 1e-3

    @property
    def _down_mbps(self) -> float:
        return self.downlink_mbps if self.downlink_mbps > 0 else self.uplink_mbps

    def downlink_seconds(self, nbytes: float) -> float:
        return nbytes * 8.0 / (self._down_mbps * 1e6)

    def downlink_power_mw(self) -> float:
        alpha = self.alpha_d_mw_per_mbps if self.alpha_d_mw_per_mbps > 0 \
            else self.alpha_mw_per_mbps
        return alpha * self._down_mbps + self.beta_mw

    def downlink_energy_mj(self, nbytes: float) -> float:
        return self.downlink_seconds(nbytes) * 1e3 * \
            self.downlink_power_mw() * 1e-3


# Table III (average US 3G/4G/Wi-Fi, opensignal/speedtest 2017); downlink
# throughput from the same surveys, alpha_d from Huang et al. MobiSys'12
NETWORKS = {
    "3g": WirelessNetwork("3g", 1.1, 868.98, 817.88,
                          downlink_mbps=3.15, alpha_d_mw_per_mbps=122.12),
    "4g": WirelessNetwork("4g", 5.85, 438.39, 1288.04,
                          downlink_mbps=16.31, alpha_d_mw_per_mbps=51.97),
    "wifi": WirelessNetwork("wifi", 18.88, 283.17, 132.86,
                            downlink_mbps=54.97, alpha_d_mw_per_mbps=137.01),
}


@dataclass(frozen=True)
class Interconnect:
    """TPU-deployment analogue of the wireless link: the slow boundary the
    butterfly compresses.  bytes/s and an energy proxy (pJ/byte).
    Symmetric: downlink == uplink."""
    name: str
    bytes_per_s: float
    pj_per_byte: float = 10.0

    def uplink_seconds(self, nbytes: float) -> float:
        return nbytes / self.bytes_per_s

    def uplink_energy_mj(self, nbytes: float) -> float:
        return nbytes * self.pj_per_byte * 1e-9

    def downlink_seconds(self, nbytes: float) -> float:
        return self.uplink_seconds(nbytes)

    def downlink_energy_mj(self, nbytes: float) -> float:
        return self.uplink_energy_mj(nbytes)


# inter-pod boundary: ~1 ICI link worth of bandwidth per device pair crossing
# pods (DCN-class in real deployments; we use the assignment's 50 GB/s/link).
INTER_POD = Interconnect("inter_pod", 50e9)
INTRA_POD = Interconnect("intra_pod_ici", 50e9 * 4)   # 4 links per chip


def get_link(name: str):
    """Uplink model by name — wireless (paper Table III) or interconnect.

    Anything with ``uplink_seconds``/``uplink_energy_mj`` works as a link
    model for the split-serving runtime (runtime/wire.py)."""
    if name in NETWORKS:
        return NETWORKS[name]
    if name == INTER_POD.name:
        return INTER_POD
    if name == INTRA_POD.name:
        return INTRA_POD
    known = sorted(NETWORKS) + [INTER_POD.name, INTRA_POD.name]
    raise KeyError(f"unknown link {name!r}; known: {known}")
