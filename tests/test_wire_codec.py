"""Entropy wire codec (core/wire_codec.py): exact round-trips under
friendly and adversarial priors, rate estimation vs the real encoder, the
progressive bitplane schedule, and the differentiable rate term."""
import numpy as np
import pytest

from repro.core import wire_codec as wc


def _codes(shape, bits, seed, spread=3.0):
    """Roughly-Gaussian signed codes, the shape butterfly rows produce."""
    rng = np.random.default_rng(seed)
    qmax = 2 ** (bits - 1) - 1
    c = np.round(rng.normal(0.0, qmax / spread, size=shape))
    return np.clip(c, -qmax - 1, qmax).astype(np.int8)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("d_r", [1, 16, 32])
@pytest.mark.parametrize("T", [0, 1, 7, 256])
def test_roundtrip_data_prior(bits, d_r, T):
    codes = _codes((T, d_r), bits, seed=T * 100 + d_r)
    prior = wc.WirePrior.from_counts(wc.channel_counts(codes, bits), bits)
    data = wc.encode(codes, prior)
    back = wc.decode(data, prior, codes.shape)
    assert np.array_equal(back, codes)


@pytest.mark.parametrize("bits", [4, 8])
def test_roundtrip_default_prior(bits):
    codes = _codes((64, 16), bits, seed=3)
    prior = wc.WirePrior.default(16, bits)
    back = wc.decode(wc.encode(codes, prior), prior, codes.shape)
    assert np.array_equal(back, codes)


def test_roundtrip_mismatched_prior():
    """Adversarial: the prior was fit on DIFFERENT data (every symbol still
    has freq >= 1 by construction), so coding is inefficient but exact."""
    codes = _codes((128, 8), 8, seed=11, spread=1.2)
    other = _codes((128, 8), 8, seed=99, spread=20.0)   # near-degenerate
    prior = wc.WirePrior.from_counts(wc.channel_counts(other, 8), 8)
    data = wc.encode(codes, prior)
    assert np.array_equal(wc.decode(data, prior, codes.shape), codes)
    # mismatch costs bytes relative to the matched prior, never correctness
    matched = wc.WirePrior.from_counts(wc.channel_counts(codes, 8), 8)
    assert len(data) >= len(wc.encode(codes, matched))


def test_degenerate_single_symbol_source():
    """All-zero codes compress to near the per-payload overhead floor."""
    codes = np.zeros((128, 8), np.int8)
    prior = wc.WirePrior.from_counts(wc.channel_counts(codes, 8), 8)
    data = wc.encode(codes, prior)
    assert np.array_equal(wc.decode(data, prior, codes.shape), codes)
    raw_int8 = codes.size
    assert len(data) < raw_int8 / 4
    assert len(data) >= wc.payload_overhead_bytes(8)


def test_uniform_source_bounded_expansion():
    """Uniform random codes are incompressible: the coded stream must stay
    within the rANS per-symbol slack plus the fixed payload overhead."""
    rng = np.random.default_rng(7)
    codes = rng.integers(-128, 128, size=(256, 16)).astype(np.int8)
    prior = wc.WirePrior.from_counts(wc.channel_counts(codes, 8), 8)
    data = wc.encode(codes, prior)
    assert np.array_equal(wc.decode(data, prior, codes.shape), codes)
    assert len(data) <= codes.size * 1.02 + wc.payload_overhead_bytes(16) + 16


def test_corrupt_stream_rejected():
    codes = _codes((32, 8), 8, seed=5)
    prior = wc.WirePrior.from_counts(wc.channel_counts(codes, 8), 8)
    data = bytearray(wc.encode(codes, prior))
    data[:4] = (9999).to_bytes(4, "little")   # lie about the row count
    with pytest.raises(ValueError):
        wc.decode(bytes(data), prior, (9999, 8))


def test_estimate_tracks_actual():
    """estimate_coded_bytes (the fused kernel's consumer) stays within a
    few percent of the real encoder."""
    codes = _codes((256, 32), 8, seed=21)
    prior = wc.WirePrior.from_counts(wc.channel_counts(codes, 8), 8)
    actual = len(wc.encode(codes, prior))
    est = wc.estimate_coded_bytes(wc.channel_counts(codes, 8), prior)
    assert abs(est - actual) / actual < 0.05


def test_predicted_code_bytes_deterministic():
    """The planner's nominal-rate prediction is pure integer math (replay
    byte-identity depends on it) and monotone in the symbol count."""
    vals = [wc.predicted_code_bytes(n) for n in range(0, 4096, 17)]
    assert all(isinstance(v, int) for v in vals)
    assert vals == sorted(vals)
    # 3.5 bits/symbol nominal rate
    assert wc.predicted_code_bytes(16) == 7


def test_coarse_refine_schedule():
    codes = _codes((64, 16), 8, seed=13)
    coarse = wc.coarse_codes(codes)
    # refinement is confined to the low planes: adding it back is exact
    assert np.array_equal(coarse + (codes - coarse), codes)
    shift = 8 - wc.COARSE_BITS
    assert np.all(np.abs(codes.astype(np.int64) -
                         coarse.astype(np.int64)) < (1 << shift))
    c, r = wc.split_coarse_refine(1000, 64)
    assert c + r >= 1000 + 64          # the split never invents compression
    assert c >= 64                     # scales always ride with the coarse chunk


def test_rate_bits_differentiable():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    r = jax.random.normal(jax.random.key(0), (32, 16), jnp.float32)
    val = wc.rate_bits(r, bits=8)
    assert np.isfinite(float(val))
    g = jax.grad(lambda x: wc.rate_bits(x, bits=8))(r)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.sum(jnp.abs(g))) > 0.0


def test_roundtrip_property_based():
    """Hypothesis sweep over shapes/bit-widths/distributions (skipped when
    hypothesis isn't in the environment)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        T=st.integers(min_value=0, max_value=40),
        d_r=st.integers(min_value=1, max_value=24),
        bits=st.sampled_from([4, 8]),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        spread=st.floats(min_value=0.3, max_value=30.0,
                         allow_nan=False, allow_infinity=False),
    )
    @hyp.settings(max_examples=40, deadline=None)
    def inner(T, d_r, bits, seed, spread):
        codes = _codes((T, d_r), bits, seed=seed, spread=spread)
        prior = wc.WirePrior.from_counts(wc.channel_counts(codes, bits),
                                         bits)
        assert np.array_equal(
            wc.decode(wc.encode(codes, prior), prior, codes.shape), codes)

    inner()
