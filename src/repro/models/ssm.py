"""Mamba2 (SSD) block: chunked state-space dual form for train/prefill and a
recurrent step for decode.

The chunked algorithm follows Dao & Gu 2024 (SSD): within a chunk the output
is a masked-decay attention-like matmul (MXU-friendly); across chunks a
single lax.scan carries the (B, H, P, N) state.  All decay exponents are
differences of a *decreasing* cumulative sum (A<0, dt>0) so every exp() is
<= 1 and bf16-safe.

State for decode: {"ssm": (B, H, P, N), "conv": (B, W-1, d_conv_channels)}.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import dense_init, dense_spec, rms_norm
from repro.models.parallel import ParallelContext


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.num_heads * s.head_dim
    conv_ch = d_inner + 2 * s.state_dim
    return s, d_inner, conv_ch


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig, dtype):
    s, d_inner, conv_ch = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_inner + 2 * s.state_dim + s.num_heads   # z, x, B, C, dt
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "in_proj": dense_init(k1, d, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_ch), jnp.float32)
                   * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((s.num_heads,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, s.num_heads, dtype=jnp.float32)),
        "D": jnp.ones((s.num_heads,), jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(k3, d_inner, d, dtype, scale=1.0 / d_inner),
    }
    specs = {
        "in_proj": dense_spec((d, proj_out), 1),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "dt_bias": P(None),
        "A_log": P(None),
        "D": P(None),
        "norm_w": P(None),
        "out_proj": dense_spec((d_inner, d), 0),
    }
    return params, specs


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, d_inner, conv_ch = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, s.num_heads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssm_state_spec(batch_axis) -> dict:
    return {"ssm": P(batch_axis, None, None, None),
            "conv": P(batch_axis, None, None)}


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------


def _split_proj(params, x, cfg: ModelConfig):
    s, d_inner, conv_ch = _dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:].astype(jnp.float32)     # (..., H)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, width: int):
    """Depthwise causal conv over (B, S, C) via width-shifted adds."""
    out = xbc * conv_w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :xbc.shape[1]]
        out = out + shifted * conv_w[-1 - i]
    return jax.nn.silu(out + conv_b)


def _gated_out(params, y, z, cfg: ModelConfig):
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    return y @ params["out_proj"]


# ---------------------------------------------------------------------------
# full-sequence SSD (train / prefill)
# ---------------------------------------------------------------------------


def mamba_fullseq(params, x, *, cfg: ModelConfig, return_state: bool = False):
    s, d_inner, conv_ch = _dims(cfg)
    Bsz, S, _ = x.shape
    H, Pd, N, L = s.num_heads, s.head_dim, s.state_dim, s.chunk_size
    L = min(L, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    C = S // L

    z, xbc, dt = _split_proj(params, x, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"], s.conv_width)
    xs = xbc[..., :d_inner].reshape(Bsz, S, H, Pd)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]

    dt = jax.nn.softplus(dt + params["dt_bias"])                  # (B,S,H) f32
    A = -jnp.exp(params["A_log"])                                 # (H,) < 0
    a = dt * A                                                    # (B,S,H) < 0

    # chunked views
    xc = xs.reshape(Bsz, C, L, H, Pd)
    dtc = dt.reshape(Bsz, C, L, H)
    Bc = Bm.reshape(Bsz, C, L, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, C, L, N).astype(jnp.float32)
    ac = a.reshape(Bsz, C, L, H)
    cum = jnp.cumsum(ac, axis=2)                                  # (B,C,L,H)

    # ---- intra-chunk (decay-masked attention) -----------------------------
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,C,L,L,H) i-j
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    # mask BEFORE exp: masked (i<j) entries are positive and can overflow;
    # where(mask, exp(seg), 0) would make the backward 0 * inf = NaN
    decay = jnp.exp(jnp.where(mask, seg, -1e9))                   # <= 1
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    G = (scores[..., None] * decay).astype(x.dtype)               # (B,C,L,L,H)
    xdt = (xc * dtc[..., None].astype(x.dtype))
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", G, xdt)

    # ---- chunk summary states ---------------------------------------------
    last = cum[:, :, -1:, :]                                      # (B,C,1,H)
    w = jnp.exp(last - cum) * dtc                                 # (B,C,L,H)
    S_chunk = jnp.einsum("bcln,bclh,bclhp->bchpn",
                         Bc, w, xc.astype(jnp.float32))           # (B,C,H,P,N)

    # ---- inter-chunk scan --------------------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :])                       # (B,C,H)

    def step(state, inputs):
        s_c, dec_c, C_c, cum_c = inputs
        # y from previous state, decayed to each position in the chunk
        y = jnp.einsum("bln,bhpn->blhp", C_c, state) * \
            jnp.exp(cum_c)[..., None].transpose(0, 1, 2, 3)
        new = state * dec_c[:, :, None, None] + s_c
        return new, y

    init = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    # scan over chunk axis: move C to leading
    xs_scan = (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2),
               Cc.transpose(1, 0, 2, 3), cum.transpose(1, 0, 2, 3))
    final_state, y_inter = jax.lax.scan(step, init, xs_scan)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)                    # (B,C,L,H,P)

    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, Pd)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    out = _gated_out(params, y, z, cfg)
    if return_state:
        # conv state must come from the *pre-activation* conv input stream
        return out, {"ssm": final_state, "conv": _conv_tail(params, x, cfg)}
    return out, None


def _conv_tail(params, x, cfg: ModelConfig):
    """Last (W-1) pre-conv channel rows, for seeding decode."""
    s, d_inner, conv_ch = _dims(cfg)
    _, xbc, _ = _split_proj(params, x, cfg)
    return xbc[:, -(s.conv_width - 1):, :]


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def mamba_decode(params, x, state, *, cfg: ModelConfig):
    """x: (B, 1, d); state: {"ssm": (B,H,P,N) f32, "conv": (B,W-1,Cc)}."""
    s, d_inner, conv_ch = _dims(cfg)
    Bsz = x.shape[0]
    H, Pd, N = s.num_heads, s.head_dim, s.state_dim

    z, xbc_new, dt = _split_proj(params, x, cfg)                  # (B,1,*)
    window = jnp.concatenate([state["conv"], xbc_new], axis=1)    # (B,W,Cc)
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv + params["conv_b"].astype(jnp.float32))[:, None, :]
    new_conv_state = window[:, 1:, :]

    xs = xbc[..., :d_inner].reshape(Bsz, H, Pd)
    Bm = xbc[..., d_inner:d_inner + N].reshape(Bsz, N)
    Cm = xbc[..., d_inner + N:].reshape(Bsz, N)

    dt = jax.nn.softplus(dt[:, 0] + params["dt_bias"])            # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                       # (B,H)

    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32), Bm)
    ssm = state["ssm"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    out = _gated_out(params, y, z, cfg)
    return out, {"ssm": ssm, "conv": new_conv_state.astype(state["conv"].dtype)}
