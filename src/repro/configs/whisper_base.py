"""whisper-base [audio] — encoder-decoder; mel+conv frontend is a STUB per the
assignment carve-out: ``input_specs`` supplies precomputed frame embeddings
(batch, 1500, d_model). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, register


@register("whisper-base")
def whisper_base() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        arch_type="audio",
        num_layers=6,                 # decoder layers
        d_model=512,
        num_heads=8,
        num_kv_heads=8,               # whisper is MHA (kv == q heads)
        head_dim=64,
        d_ff=2048,
        vocab_size=51865,
        act="gelu",
        rope_theta=1e4,               # (whisper uses learned pos; we use RoPE-free sinusoid)
        tie_embeddings=True,
        is_encdec=True,
        encoder_layers=6,
        encoder_frames=1500,
        source="arXiv:2212.04356 (whisper-base: 6+6 layers, d=512, 8 heads)",
    )
