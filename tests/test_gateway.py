"""Serving gateway invariants (DESIGN.md section 17): priority ordering,
shed conservation, circuit-breaker transitions on the virtual clock, LRU
response-cache byte-identity, the GatewayPolicy-unset == legacy-FIFO
byte-identity contract, and a 10^5-request heavy-tailed run terminating in
sane wall time."""
import dataclasses
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.runtime.actors import SimRequest
from repro.runtime.gateway import (CircuitBreaker, GatewayPolicy, JobQueue,
                                   ResponseCache)
from repro.runtime.simulator import (Arrival, CellSpec, SimConfig,
                                     Simulation, WorkloadSpec, run_sim)
from repro.runtime.telemetry import RequestTrace


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


def _req(uid, slo="interactive"):
    return SimRequest(trace=RequestTrace(uid=uid, device=0, mode="split",
                                         wire_mode="int8", split=1,
                                         prompt_len=8, slo_class=slo),
                      tokens=None, max_new_tokens=1)


# the bench's cloud-bound 2-pod topology: negligible wire, the shared
# slot pool + background tenants are the contended resource
PODS = (CellSpec(name="pod-jet", network="inter_pod", num_devices=4,
                 device="jetson"),
        CellSpec(name="pod-ph", network="inter_pod", num_devices=4,
                 device="phone"))


def flash_cfg(workload, gateway, **kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    topology=PODS, num_requests=0, prompt_len=32,
                    max_new_tokens=16, numerics=False, seed=0,
                    max_concurrent=4, workload=workload, gateway=gateway,
                    background_load=lambda t: 0.95)
    defaults.update(kw)
    return SimConfig(**defaults)


# ---------------------------------------------------------------------------
# GatewayPolicy + grammar
# ---------------------------------------------------------------------------


def test_policy_parse_grammar():
    p = GatewayPolicy.parse("priority,shed,slo=40/400,reserve=1,cache=64,"
                            "hedge=0.03,breaker,replicas=3,spinup=0.1")
    assert p.priority and p.shed and p.breaker and p.hedge and p.autoscale
    assert p.slo_interactive_ms == 40.0 and p.slo_batch_ms == 400.0
    assert p.reserved_slots == 1 and p.cache_size == 64
    assert p.hedge_delay_s == 0.03
    assert p.max_replicas == 3 and p.spin_up_s == 0.1
    # slo=X/inf means batch is never shed; bare slo implies shed
    p2 = GatewayPolicy.parse("slo=100/inf")
    assert p2.shed and p2.slo_batch_ms is None
    with pytest.raises(ValueError):
        GatewayPolicy.parse("priority,bogus=1")


def test_policy_default_is_all_off():
    p = GatewayPolicy()
    assert not (p.priority or p.shed or p.breaker or p.hedge or p.autoscale)
    assert p.cache_size == 0


# ---------------------------------------------------------------------------
# priority queue
# ---------------------------------------------------------------------------


def test_jobqueue_fifo_when_priority_off():
    q = JobQueue(priority=False)
    reqs = [_req(i, "batch" if i % 2 else "interactive") for i in range(8)]
    for r in reqs:
        q.append(r)
    assert [q.popleft().trace.uid for _ in range(8)] == list(range(8))


def test_jobqueue_interactive_never_behind_batch():
    q = JobQueue(priority=True)
    order = ["batch", "batch", "interactive", "batch", "interactive"]
    reqs = [_req(i, slo) for i, slo in enumerate(order)]
    for r in reqs:
        q.append(r)
    popped = [q.popleft().trace.uid for _ in range(len(reqs))]
    # both interactive first (in arrival order), then the batch in order
    assert popped == [2, 4, 0, 1, 3]


def test_jobqueue_deque_surface():
    q = JobQueue(priority=True)
    reqs = [_req(i, "batch" if i == 1 else "interactive") for i in range(3)]
    for r in reqs:
        q.append(r)
    assert len(q) == 3 and reqs[1] in q
    assert q.peek() is reqs[0]
    q.remove(reqs[0])
    assert len(q) == 2 and reqs[0] not in q
    assert [r.trace.uid for r in q] == [2, 1]    # iter in priority order
    q.clear()
    assert len(q) == 0 and not q
    with pytest.raises(IndexError):
        q.popleft()


# ---------------------------------------------------------------------------
# circuit breaker (pure virtual-time state machine)
# ---------------------------------------------------------------------------


def test_breaker_open_halfopen_close_cycle():
    cb = CircuitBreaker(fail_threshold=3, halfopen_after_s=0.5, probes=2)
    assert cb.allow(0.0) and cb.state == "closed"
    assert not cb.record_failure(0.10)
    assert not cb.record_failure(0.11)
    assert cb.record_failure(0.12)          # third consecutive: opens
    assert cb.state == "open" and not cb.allow(0.2)
    # cooldown elapses -> half_open admits exactly `probes` trials
    assert cb.allow(0.12 + 0.5)
    assert cb.state == "half_open"
    assert cb.allow(0.65) and not cb.allow(0.66)
    assert not cb.record_success(0.70)      # first probe success
    assert cb.record_success(0.71)          # second: closes
    assert cb.state == "closed" and cb.allow(0.72)


def test_breaker_halfopen_failure_reopens():
    cb = CircuitBreaker(fail_threshold=1, halfopen_after_s=0.5, probes=1)
    assert cb.record_failure(0.0) and cb.state == "open"
    assert cb.allow(0.6) and cb.state == "half_open"
    assert cb.record_failure(0.61)          # probe failed: re-open
    assert cb.state == "open" and not cb.allow(0.62)
    # success after the next cooldown closes it again
    assert cb.allow(1.2) and cb.record_success(1.25)
    assert cb.state == "closed"


def test_breaker_success_resets_failure_streak():
    cb = CircuitBreaker(fail_threshold=2, halfopen_after_s=0.5, probes=1)
    cb.record_failure(0.0)
    cb.record_success(0.1)                  # streak broken
    assert not cb.record_failure(0.2)       # needs 2 consecutive again
    assert cb.state == "closed"


# ---------------------------------------------------------------------------
# byte-identity: GatewayPolicy() == gateway=None == legacy FIFO
# ---------------------------------------------------------------------------


def test_gateway_unset_byte_identical_timing():
    base = timing_cfg()
    a = run_sim(base).to_json()
    b = run_sim(timing_cfg(gateway=GatewayPolicy())).to_json()
    c = run_sim(timing_cfg(gateway=None)).to_json()
    assert a == b == c


def test_gateway_unset_byte_identical_numerics():
    kw = dict(num_devices=2, num_requests=4, numerics=True, prompt_len=8,
              max_new_tokens=2)
    a = run_sim(timing_cfg(**kw)).to_json()
    b = run_sim(timing_cfg(gateway=GatewayPolicy(), **kw)).to_json()
    assert a == b


def test_gateway_runs_are_deterministic():
    wl = WorkloadSpec(kind="flash", rate=6.0, n=600, interactive=0.25,
                      alpha=1.5, at=1.0, dur=5.0, burst=15.0)
    gw = "priority,shed,slo=150/1000,reserve=1,breaker,hedge"
    a = run_sim(flash_cfg(wl, gw)).to_json()
    b = run_sim(flash_cfg(wl, gw)).to_json()
    assert a == b


def test_gateway_record_replay_byte_identical(tmp_path):
    wl = WorkloadSpec(kind="flash", rate=6.0, n=400, interactive=0.25,
                      alpha=1.5, at=1.0, dur=4.0, burst=15.0)
    gw = "priority,shed,slo=150/1000,reserve=1"
    sim = Simulation(flash_cfg(wl, gw))
    path = str(tmp_path / "trace.jsonl")
    sim.record_trace(path)
    recorded = sim.run().to_json()
    from repro.runtime.simulator import trace_arrivals
    arrivals = trace_arrivals(path)
    # the SLO classes survive record -> replay (arrival-trace-v3)
    assert {a.slo for a in arrivals} == {"interactive", "batch"}
    replayed = Simulation(flash_cfg(None, gw, arrivals=arrivals)).run()
    assert recorded == replayed.to_json()


# ---------------------------------------------------------------------------
# shedding + conservation
# ---------------------------------------------------------------------------


def test_shed_conservation_and_batch_absorbs():
    wl = WorkloadSpec(kind="flash", rate=6.0, n=3000, interactive=0.25,
                      alpha=1.5, at=2.0, dur=20.0, burst=30.0)
    tel = run_sim(flash_cfg(wl, "priority,shed,slo=150/600,reserve=1"))
    s = tel.summary()
    assert s["n_done"] + s["n_failed"] + s["n_shed"] == 3000
    assert s["n_shed"] > 0
    assert tel.counters["gateway_shed"] == s["n_shed"]
    cls = tel.class_summary()
    # priority + admission control: the interactive class is never shed
    # (it jumps the queue, so its predicted delay stays under SLO) while
    # batch absorbs the whole shed
    assert cls["interactive"]["n_shed"] == 0
    assert cls["batch"]["n_shed"] == s["n_shed"]
    # every shed trace is terminal and self-consistent
    for t in tel.traces:
        if t.outcome == "shed":
            assert t.failure in ("admission", "breaker_open")
            assert t.t_done >= t.t_arrival


def test_shed_protects_interactive_p99():
    wl = WorkloadSpec(kind="flash", rate=6.0, n=3000, interactive=0.25,
                      alpha=1.5, at=2.0, dur=20.0, burst=30.0)
    off = run_sim(flash_cfg(wl, None)).class_summary()
    on = run_sim(
        flash_cfg(wl, "priority,shed,slo=150/600,reserve=1")
    ).class_summary()
    ratio = off["interactive"]["latency_p99_ms"] / \
        on["interactive"]["latency_p99_ms"]
    assert ratio >= 3.0, f"interactive p99 only improved {ratio:.2f}x"


# ---------------------------------------------------------------------------
# LRU response cache
# ---------------------------------------------------------------------------


def test_response_cache_lru_eviction():
    cache = ResponseCache(size=2)
    r1, r2, r3 = (_req(i) for i in range(3))
    for i, r in enumerate((r1, r2, r3)):
        r.tokens = np.full((4,), i, np.int32)
    k1, k2, k3 = (ResponseCache.key(r) for r in (r1, r2, r3))
    cache.put(k1, [1, 2]); cache.put(k2, [3, 4])
    assert cache.get(k1) == (1, 2)          # touch k1: k2 becomes LRU
    cache.put(k3, [5, 6])
    assert cache.get(k2) is None and len(cache) == 2
    assert cache.get(k1) == (1, 2) and cache.get(k3) == (5, 6)
    # timing-only requests (no prompt) never enter the cache
    assert ResponseCache.key(_req(9)) is None


def test_cache_hit_is_byte_identical():
    cfg = small_cfg()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=(8,),
                          dtype=np.int64).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, size=(8,),
                         dtype=np.int64).astype(np.int32)
    arrivals = [Arrival(device=0, t=0.0, tokens=prompt),
                Arrival(device=1, t=0.05, tokens=other),
                Arrival(device=0, t=5.0, tokens=prompt)]   # repeat
    sim = Simulation(timing_cfg(
        numerics=True, num_devices=2, num_requests=3, prompt_len=8,
        max_new_tokens=3, arrivals=arrivals,
        gateway=GatewayPolicy(cache_size=8)))
    tel = sim.run()
    first, _, repeat = sim.requests
    assert repeat.trace.cache_hit and not first.trace.cache_hit
    assert repeat.cached_ids == tuple(first.engine_req.generated)
    assert tel.counters["gateway_cache_hits"] == 1
    assert tel.summary()["n_cache_hits"] == 1
    # the hit never touched the accelerator: zero cloud time
    assert repeat.trace.t_cloud_done == repeat.trace.t_cloud_start


# ---------------------------------------------------------------------------
# hedged retries + breaker in the loop + autoscale
# ---------------------------------------------------------------------------


def test_hedge_duplicates_are_deduped():
    # a slow 3g uplink: interactive sends stuck past the hedge delay get a
    # duplicate, the cloud drops whichever lands second, everyone finishes
    tel = run_sim(timing_cfg(
        num_requests=32, arrival_rate=40.0,
        workload="poisson:rate=40,n=32,interactive=0.5",
        gateway=GatewayPolicy(hedge=True, hedge_delay_s=0.005)))
    s = tel.summary()
    assert s["n_done"] == 32 and s["n_shed"] == 0
    assert s["n_hedged"] > 0
    assert tel.counters["gateway_hedges"] == sum(
        t.hedges for t in tel.traces)
    # only interactive requests hedge
    assert all(t.slo_class == "interactive"
               for t in tel.traces if t.hedges)


def test_breaker_opens_under_cloud_outage():
    # an injected cloud outage drops payloads -> the breaker counts them
    # as failures, opens, sheds at the gate, then recovers half-open
    wl = WorkloadSpec(kind="poisson", rate=20.0, n=400, interactive=0.5)
    tel = run_sim(flash_cfg(
        wl, "breaker,shed,slo=150/1500", max_new_tokens=2,
        faults="outage@0.3+0.4", recovery=None))
    c = tel.counters
    assert c["gateway_breaker_opens"] >= 1
    assert c["gateway_breaker_shed"] > 0
    assert c["gateway_breaker_closes"] >= 1     # half-open probes recovered
    s = tel.summary()
    assert s["n_done"] + s["n_failed"] + s["n_shed"] == 400


def test_autoscale_adds_replicas_with_spinup_lag():
    wl = WorkloadSpec(kind="flash", rate=6.0, n=1500, interactive=0.25,
                      alpha=1.5, at=1.0, dur=10.0, burst=20.0)
    sim = Simulation(flash_cfg(wl, "autoscale,replicas=3,spinup=0.2"))
    tel = sim.run()
    assert tel.counters["gateway_scale_ups"] >= 1
    assert sim.server.replicas >= 2
    assert len(sim.server.slots) == sim.server.replicas * 4
    # autoscaling shortens the melt: strictly better p99 than fixed capacity
    base = run_sim(flash_cfg(wl, None)).summary()
    scaled = tel.summary()
    assert scaled["latency_p99_ms"] < base["latency_p99_ms"]


def test_autoscale_requires_timing_only():
    with pytest.raises(AssertionError):
        Simulation(timing_cfg(numerics=True, gateway="autoscale"))


def test_reserved_slots_must_leave_room():
    with pytest.raises(AssertionError):
        Simulation(timing_cfg(max_concurrent=2,
                              gateway=GatewayPolicy(reserved_slots=2)))


# ---------------------------------------------------------------------------
# scale: 10^5 heavy-tailed requests on the virtual clock
# ---------------------------------------------------------------------------


def test_pareto_100k_requests_terminate():
    wl = WorkloadSpec(kind="pareto", rate=20.0, n=100_000, alpha=1.5,
                      interactive=0.5)
    t0 = time.time()
    tel = run_sim(SimConfig(
        cfg=small_cfg(), mode="split", wire_mode="int8",
        network="inter_pod", num_devices=8, prompt_len=16,
        max_new_tokens=1, numerics=False, seed=0, max_concurrent=8,
        workload=wl, gateway="priority,shed,slo=250/2000"))
    wall = time.time() - t0
    s = tel.summary()
    assert s["n_done"] + s["n_failed"] + s["n_shed"] == 100_000
    assert wall < 120.0, f"10^5-request run took {wall:.0f}s"
