"""Config system: every architecture (and the paper's own ResNet-50) is a
frozen dataclass instance registered under its ``--arch`` id.

The full configs are exercised only through the AOT dry-run
(``launch/dryrun.py``); smoke tests use ``cfg.reduced()`` which shrinks the
same family to 2 layers / d_model<=512 / <=4 experts so it runs on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert_ff: int = 0        # llama4: one always-on shared expert
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    every: int = 1                   # MoE every N layers (llama4 interleaves: 2)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyper-params."""
    state_dim: int = 64
    num_heads: int = 32
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256
    expand: int = 2                  # d_inner = expand * d_model


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 3             # every 3rd block is sLSTM, rest mLSTM
    chunk_size: int = 64
    conv_width: int = 4


@dataclass(frozen=True)
class ButterflyConfig:
    """The paper's contribution: a trained bottleneck at a layer boundary.

    ``layer`` — the butterfly is placed after this many layers (the boundary
    between the edge stage and the cloud stage).  ``d_r`` — reduced channel
    (d_model) size.  ``wire_bits`` — wire quantization (paper: 8).
    ``rate_weight`` — weight of the entropy-rate term (expected coded
    bits/symbol of the wire codes, ``wire_codec.rate_bits``) in the training
    loss; 0 disables it (the fixed-rate baseline).  BottleNet-style: the
    reduce projection learns low-entropy codes the rANS wire codec can
    actually exploit.
    """
    layer: int
    d_r: int
    wire_bits: int = 8
    rate_weight: float = 0.0


# ---------------------------------------------------------------------------
# main config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    qk_norm: bool = False
    act: str = "silu"                # silu -> SwiGLU, gelu -> GeGLU
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # window for local attention layers
    global_every: Optional[int] = None     # gemma3: one global layer per N
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid_attn_every: Optional[int] = None  # zamba2: shared attn every N layers
    # encoder-decoder (whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1500       # stub frontend output length
    # vlm
    num_patches: int = 0             # stub vision frontend output length
    # the paper's technique (None = vanilla model)
    butterfly: Optional[ButterflyConfig] = None
    # long-context: window applied to *all* attention layers for long_500k
    long_context_window: Optional[int] = None
    dtype: str = "bfloat16"
    source: str = ""                 # citation for the config numbers

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def with_butterfly(self, layer: int, d_r: int, wire_bits: int = 8,
                       rate_weight: float = 0.0) -> "ModelConfig":
        return replace(self, butterfly=ButterflyConfig(
            layer=layer, d_r=d_r, wire_bits=wire_bits,
            rate_weight=rate_weight))

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = min(self.num_kv_heads, max(1, n_heads // 2))
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                          d_ff_expert=min(self.moe.d_ff_expert, 128),
                          shared_expert_ff=min(self.moe.shared_expert_ff, 128),
                          every=1)
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, num_heads=4, head_dim=32, state_dim=16,
                          chunk_size=32)
        xl = None
        if self.xlstm is not None:
            xl = replace(self.xlstm, slstm_every=2, chunk_size=16)
        num_layers = 2
        butterfly = None
        if self.butterfly is not None:
            butterfly = ButterflyConfig(layer=1, d_r=max(8, d_model // 8),
                                        wire_bits=self.butterfly.wire_bits)
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=64 if self.head_dim else None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            global_every=2 if self.global_every else None,
            hybrid_attn_every=2 if self.hybrid_attn_every else None,
            moe=moe, ssm=ssm, xlstm=xl,
            encoder_layers=2 if self.is_encdec else 0,
            encoder_frames=16 if self.is_encdec else self.encoder_frames,
            num_patches=8 if self.num_patches else 0,
            long_context_window=min(self.long_context_window, 64) if self.long_context_window else None,
            butterfly=butterfly,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the per-arch modules lazily so `register` runs
        import repro.configs.all  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs.all  # noqa: F401
    return sorted(_REGISTRY)


def supports_shape(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Which (arch x shape) pairs run; mirrors DESIGN.md section 5."""
    if shape.name == "long_500k":
        ok = cfg.arch_type in ("ssm", "hybrid") or cfg.xlstm is not None or \
            cfg.long_context_window is not None
        if not ok:
            return False, "pure full-attention arch: long_500k skipped (DESIGN.md 5)"
    return True, ""
