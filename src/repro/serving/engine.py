"""Batched serving engine: continuous-batching style prefill/decode with a
slot-based KV/state cache pool.

Real-engine behaviours kept: per-request positions (ragged decode), slot
reuse on completion, greedy or temperature sampling, max-token and EOS
stopping.  Kept honest-but-small: requests prefill one at a time (the
pipeline/pod path in serving/pipeline.py is the paper's split deployment;
this engine is the single-mesh baseline the paper calls "cloud-only" or
"mobile-only" depending on where it runs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.parallel import LOCAL, ParallelContext


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    generated: list = dataclasses.field(default_factory=list)
    logits_history: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, built: M.BuiltModel, *, max_batch: int = 8,
                 max_len: int = 512, pctx: ParallelContext = LOCAL, seed: int = 0):
        self.params = params
        self.built = built
        self.cfg = built.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.pctx = pctx
        dt = jnp.dtype(self.cfg.dtype)
        self.cache = [tfm.init_stage_cache(list(segs), self.cfg, max_batch,
                                           max_len, dt)
                      for segs in built.stages]
        self.positions = np.zeros((max_batch,), np.int32)   # next write pos
        self.active: List[Optional[Request]] = [None] * max_batch
        self.key = jax.random.key(seed)
        self._decode = jax.jit(self._decode_fn)
        self._uid = 0

    # ------------------------------------------------------------------ api
    def submit(self, prompt, max_new_tokens: int = 32, temperature: float = 0.0,
               eos_id: Optional[int] = None) -> Request:
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id)
        self._uid += 1
        slot = self._free_slot()
        self._prefill_into(slot, req)
        return req

    def submit_prefilled(self, prompt_len: int, caches, last_logits,
                         max_new_tokens: int = 32, temperature: float = 0.0,
                         eos_id: Optional[int] = None) -> Request:
        """Admit a request whose prefill ran elsewhere (the split runtime's
        edge/cloud halves): inject its per-stage caches into a free slot and
        sample the first token from the externally computed last-position
        logits.  ``caches`` must match the engine's stage-cache pytree with
        batch dim 1; seq dims shorter than ``max_len`` are padded."""
        assert prompt_len < self.max_len, "prompt exceeds cache"
        req = Request(self._uid, np.zeros((prompt_len,), np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_id=eos_id)
        self._uid += 1
        slot = self._free_slot()
        self._write_slot(slot, caches)
        self.positions[slot] = prompt_len
        self.active[slot] = req
        last_logits = jnp.asarray(last_logits)
        req.logits_history.append(jax.device_get(last_logits))
        tok = self._sample(last_logits, req)
        req.generated.append(tok)
        if (req.eos_id is not None and tok == req.eos_id) or \
                req.max_new_tokens <= 1:
            req.done = True
            self.active[slot] = None
        return req

    @property
    def num_active(self) -> int:
        return sum(1 for r in self.active if r is not None)

    def run(self, requests_done: Callable[[], bool] = None, max_steps: int = 10_000):
        steps = 0
        while any(r is not None for r in self.active) and steps < max_steps:
            self.step()
            steps += 1

    # ------------------------------------------------------------- internals
    def _free_slot(self) -> int:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        raise RuntimeError("engine full; drain before submitting")

    def _prefill_into(self, slot: int, req: Request):
        S = len(req.prompt)
        assert S < self.max_len, "prompt exceeds cache"
        batch = {"tokens": jnp.asarray(req.prompt[None])}
        logits, caches = M.forward_prefill(self.params, self.built, batch,
                                           self.pctx)
        self._write_slot(slot, caches)
        self.positions[slot] = S
        self.active[slot] = req
        req.logits_history.append(jax.device_get(logits[0, -1]))
        tok = self._sample(logits[0, -1], req)
        req.generated.append(tok)
        if (req.eos_id is not None and tok == req.eos_id) or \
                req.max_new_tokens <= 1:
            req.done = True
            self.active[slot] = None

    def _write_slot(self, slot: int, req_cache):
        """Copy a single-request cache into batch slot ``slot`` of the pool,
        padding the seq axis of attention caches up to max_len/window."""
        def copy(pool, new):
            # leaves: stacked (repeats, B, ...) pools vs (repeats, 1, ...) new
            pad = [(0, 0)] * new.ndim
            changed = False
            for ax in range(2, new.ndim):
                if new.shape[ax] < pool.shape[ax]:
                    pad[ax] = (0, pool.shape[ax] - new.shape[ax])
                    changed = True
            if changed:
                new = jnp.pad(new, pad)
            start = [0, slot] + [0] * (new.ndim - 2)
            return jax.lax.dynamic_update_slice(pool, new.astype(pool.dtype),
                                                tuple(start))

        self.cache = jax.tree.map(copy, self.cache, req_cache)

    def _decode_fn(self, params, tokens, caches, pos):
        return M.forward_decode(params, self.built, tokens, caches, pos,
                                self.pctx)

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / req.temperature))

    def step(self):
        """One batched decode step over all active slots."""
        if not any(r is not None for r in self.active):
            return
        last = np.zeros((self.max_batch, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.generated:
                last[i, 0] = r.generated[-1]
        # .copy() is load-bearing: on the CPU backend jnp.asarray can alias
        # the numpy buffer zero-copy, and the in-place `positions[i] += 1`
        # below would race with the still-dispatching decode (observed as a
        # rare wrong-slot cache write under load)
        pos = jnp.asarray(self.positions.copy())
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache, pos)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            self.positions[i] += 1
            tok = self._sample(logits[i, 0], r)
            r.logits_history.append(jax.device_get(logits[i, 0]))
            r.generated.append(tok)
            if (r.eos_id is not None and tok == r.eos_id) or \
                    len(r.generated) >= r.max_new_tokens or \
                    self.positions[i] >= self.max_len - 1:
                r.done = True
                self.active[i] = None
