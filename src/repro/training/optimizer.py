"""AdamW + schedules in pure JAX (no optax dependency).

Optimizer state is a pytree parallel to params: {mu, nu, step}.  Under the
production mesh the launcher shards mu/nu with the same PartitionSpecs as
the params (optimizer-state FSDP comes for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# adamw
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: Optional[float] = 1.0


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = cfg.lr(step)
    if cfg.grad_clip is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = global_norm(grads)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:                        # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
