"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel body
executes with jnp semantics, which is how correctness is validated.  On a
real TPU backend the same calls compile through Mosaic.  ``use_pallas()``
picks the implementation; callers can force the reference path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.butterfly_kernel import (
    butterfly_dequant_restore_kernel,
    butterfly_dequant_restore_norm_kernel,
    butterfly_reduce_quant_bincount_kernel,
    butterfly_reduce_quant_kernel,
)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel


def interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, multiple: int, axis: int):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# Row counts at or below this skip the Pallas grid entirely: a decode step's
# (B, 1, d) residual row would otherwise pad to an 8-row tile and pay the
# pallas_call dispatch for a single MXU-tile of work.  The fast path runs the
# identical math (f32-accumulated dot + absmax quant), so kernel and fast
# path are bitwise-equal in interpret mode.
_FAST_PATH_ROWS = 8


def decode_row_block(n_rows: int = 1, block_t: int = 256) -> int:
    """The kernel block size the wrappers below pick for an ``n_rows``-row
    call — exposed so hot-path callers (the split bank's compile cache) can
    derive it once and fold it into their cache keys instead of re-deriving
    it per call."""
    return min(block_t, max(_FAST_PATH_ROWS, n_rows))


def _reduce_quant_rows(xf, w_reduce, qmax: int):
    r = jax.lax.dot_general(xf, w_reduce, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    absmax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(r / scale), -qmax - 1, qmax)
    return codes.astype(jnp.int8), scale


@functools.partial(jax.jit, static_argnames=("bits", "block_t"))
def butterfly_reduce_quant(x, w_reduce, *, bits: int = 8,
                           block_t: int = 256) -> Tuple[jax.Array, jax.Array]:
    """x: (..., d) -> (codes (..., d_r) int8, scales (..., 1) f32)."""
    assert bits <= 8, "fused codec emits int8 codes; wider wires go eager"
    shape = x.shape
    d = shape[-1]
    d_r = w_reduce.shape[1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    if T <= _FAST_PATH_ROWS:                   # (B, 1, d) decode-row fast path
        codes, scales = _reduce_quant_rows(xf, w_reduce,
                                           2 ** (bits - 1) - 1)
        return (codes.reshape(*shape[:-1], d_r),
                scales.reshape(*shape[:-1], 1))
    block = decode_row_block(T, block_t)
    xf, pad_t = _pad_to(xf, block, 0)
    codes, scales = butterfly_reduce_quant_kernel(
        xf, w_reduce, bits=bits, block_t=block, interpret=interpret_mode())
    if pad_t:
        codes, scales = codes[:T], scales[:T]
    return codes.reshape(*shape[:-1], d_r), scales.reshape(*shape[:-1], 1)


def _channel_bincount(codes, qmax: int, nsym: int):
    sym = codes.astype(jnp.int32) + (qmax + 1)
    ks = jnp.arange(nsym, dtype=jnp.int32)[None, None, :]
    return jnp.sum((sym[:, :, None] == ks).astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("bits", "block_t"))
def butterfly_reduce_quant_bincount(x, w_reduce, *, bits: int = 8,
                                    block_t: int = 256):
    """Fused reduce+quant+entropy-histogram: x (..., d) ->
    (codes (..., d_r) int8, scales (..., 1) f32, counts (d_r, 2**bits) i32).

    ``counts`` is the per-channel symbol histogram of the emitted codes —
    the input ``wire_codec.estimate_coded_bytes`` needs to predict the
    entropy-coded payload size on-device, produced in the same VMEM
    residency as the codes themselves.  Codes/scales are bitwise identical
    to ``butterfly_reduce_quant``."""
    assert bits <= 8, "fused codec emits int8 codes; wider wires go eager"
    shape = x.shape
    d = shape[-1]
    d_r = w_reduce.shape[1]
    qmax = 2 ** (bits - 1) - 1
    nsym = 1 << bits
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    if T <= _FAST_PATH_ROWS:                   # (B, 1, d) decode-row fast path
        codes, scales = _reduce_quant_rows(xf, w_reduce, qmax)
        counts = _channel_bincount(codes, qmax, nsym)
        return (codes.reshape(*shape[:-1], d_r),
                scales.reshape(*shape[:-1], 1), counts)
    block = decode_row_block(T, block_t)
    xf, pad_t = _pad_to(xf, block, 0)
    codes, scales, counts = butterfly_reduce_quant_bincount_kernel(
        xf, w_reduce, bits=bits, block_t=block, interpret=interpret_mode())
    if pad_t:
        codes, scales = codes[:T], scales[:T]
        # pad rows are all-zero -> they quantize to code 0 (symbol qmax+1)
        # in every channel; remove exactly those counts.
        counts = counts.at[:, qmax + 1].add(-pad_t)
    return codes.reshape(*shape[:-1], d_r), scales.reshape(*shape[:-1], 1), counts


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_t"))
def butterfly_dequant_restore(codes, scales, w_restore, *,
                              out_dtype=jnp.float32, block_t: int = 256):
    shape = codes.shape
    d_r = shape[-1]
    d = w_restore.shape[1]
    cf = codes.reshape(-1, d_r)
    sf = scales.reshape(-1, 1)
    T = cf.shape[0]
    if T <= _FAST_PATH_ROWS:                   # (B, 1, d_r) decode-row fast path
        r = cf.astype(jnp.float32) * sf
        out = jax.lax.dot_general(r, w_restore, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        return out.astype(out_dtype).reshape(*shape[:-1], d)
    block = decode_row_block(T, block_t)
    cf, pad_t = _pad_to(cf, block, 0)
    sf, _ = _pad_to(sf, block, 0)
    out = butterfly_dequant_restore_kernel(
        cf, sf, w_restore, out_dtype=out_dtype, block_t=block,
        interpret=interpret_mode())
    if pad_t:
        out = out[:T]
    return out.reshape(*shape[:-1], d)


@functools.partial(jax.jit, static_argnames=("eps", "out_dtype", "block_t"))
def butterfly_restore_norm(codes, scales, w_restore, norm_w, *,
                           eps: float = 1e-6, out_dtype=jnp.float32,
                           block_t: int = 256):
    """Fused dequant + restore + first-cloud-layer RMSNorm.

    codes: (..., d_r) int8, scales: (..., 1) -> (x (..., d), h (..., d))
    where ``x`` is the restored boundary activation (the residual-stream
    input) and ``h = rms_norm(x, norm_w)`` (the layer's norm1 output).
    Bitwise equal to butterfly_dequant_restore followed by rms_norm."""
    shape = codes.shape
    d_r = shape[-1]
    d = w_restore.shape[1]
    cf = codes.reshape(-1, d_r)
    sf = scales.reshape(-1, 1)
    T = cf.shape[0]
    if T <= _FAST_PATH_ROWS:                   # decode-row fast path
        r = cf.astype(jnp.float32) * sf
        out = jax.lax.dot_general(r, w_restore, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        x = out.astype(out_dtype)
        h = ref.rms_norm_ref(x, norm_w, eps)
        return (x.reshape(*shape[:-1], d), h.reshape(*shape[:-1], d))
    block = decode_row_block(T, block_t)
    cf, pad_t = _pad_to(cf, block, 0)
    sf, _ = _pad_to(sf, block, 0)
    x, h = butterfly_dequant_restore_norm_kernel(
        cf, sf, w_restore, norm_w.reshape(1, d), eps=eps,
        out_dtype=out_dtype, block_t=block, interpret=interpret_mode())
    if pad_t:
        x, h = x[:T], h[:T]
    return x.reshape(*shape[:-1], d), h.reshape(*shape[:-1], d)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128):
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret_mode())


@functools.partial(jax.jit, static_argnames=("eps", "block_t"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_t: int = 256):
    """x: (..., d) -> fused RMSNorm (gemma-style 1+w weight)."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    block = decode_row_block(T, block_t)
    xf, pad_t = _pad_to(xf, block, 0)
    out = rmsnorm_kernel(xf, w, eps=eps, block_t=block,
                         interpret=interpret_mode())
    if pad_t:
        out = out[:T]
    return out.reshape(shape)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    from repro.models.common import rms_norm
    return rms_norm(x, w, eps)


# reference aliases (oracles)
butterfly_reduce_quant_ref = ref.butterfly_reduce_quant_ref
butterfly_reduce_quant_bincount_ref = ref.butterfly_reduce_quant_bincount_ref
butterfly_dequant_restore_ref = ref.butterfly_dequant_restore_ref
butterfly_restore_norm_ref = ref.butterfly_restore_norm_ref
flash_attention_ref = ref.flash_attention_ref
