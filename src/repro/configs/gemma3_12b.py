"""gemma3-12b [dense] — 5 local (sliding-window 1024) : 1 global attention
pattern, 128k context.  For the ``long_500k`` shape the global layers also run
with a bounded window (``long_context_window``) which is the sub-quadratic
variant required by the assignment. [hf:google/gemma-3-1b-pt family card]"""
from repro.configs.base import ModelConfig, register


@register("gemma3-12b")
def gemma3_12b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        arch_type="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        qk_norm=True,
        act="gelu",
        rope_theta=1e6,
        tie_embeddings=True,
        sliding_window=1024,
        global_every=6,               # 5 local : 1 global
        long_context_window=32768,    # sub-quadratic variant for long_500k
        source="hf:google/gemma-3-1b-pt (family card, 12B row; 5:1 local:global)",
    )
