"""Edge-device fleet and cloud continuous-batching server.

EdgeDevice is a serial processor (one prefill at a time, like a phone's NPU):
requests queue at the device, run the edge half (layers [0, split) + the
butterfly reduce/quantize), then contend for the shared uplink.  Virtual
time stays serial per request, but the *numerics* coalesce: when a burst
queues at the device, one batched ``edge_half`` call computes every queued
request's payload (results are sliced back per request), so the jax hot
path runs at (B, S) instead of B separate batch-1 dispatches.

CloudServer is a serial accelerator running a continuous-batching loop over
the hosted partitioned models (ServingEngines over one shared-weight
``SplitModelBank`` backbone): each service turn admits every pending
prefill the slot pool can hold (serial cumulative durations — same virtual
timeline as one-at-a-time admission), then serves any streamed decode rows
that arrived over the wire, then runs batched decode steps over the active
cache-handoff slots, with service times derated by ``1/(1 - load)`` (the
paper's K_cloud congestion knob).  Cloud-half numerics batch the same way
the edge does: the first ``_prefill_done`` of a burst computes restore +
layers [split, N) for every in-flight payload of that split in one call.

The decode phase of a multi-token split request follows its
:mod:`~repro.runtime.transports` transport — ``cache_handoff`` (stage-0
cache up, decode in the engine's slot pool, ids down at completion) or
``streamed`` (edge keeps its cache, one butterfly row up and one id down
per token); both end with the response crossing the Wire's downlink.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.costs import TOKEN_BYTES
from repro.runtime.clock import EventLoop
from repro.runtime.gateway import JobQueue
from repro.runtime.split_exec import CostModel, SplitModelBank
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.tracing import NULL_TRACER
from repro.runtime.transports import get_transport
from repro.runtime.wire import Wire


@dataclass
class SimRequest:
    trace: RequestTrace
    tokens: Optional[np.ndarray] = None       # prompt (numerics mode)
    max_new_tokens: int = 1
    payload: Optional[tuple] = None           # (codes, scales, stage0_cache)
    engine_req: object = None                 # serving.engine.Request
    slot: int = -1                            # cloud slot (virtual accounting)
    # streamed-transport state (see runtime/transports.py)
    edge_cache: object = None                 # stage-0 decode cache (edge)
    edge_pos: int = 0
    cloud_cache: object = None                # stage-1 decode cache (cloud)
    cloud_pos: int = 0
    stream_row: Optional[tuple] = None        # last (payload, scales) row
    last_token: int = -1
    produced: int = 0                         # ids RECEIVED at the mobile
    stream_t0: Optional[float] = None         # RTT accounting anchor
    # progressive-transport state: the refinement bitplanes have landed
    # (always True outside progressive), and the first sampled token held
    # back while they were still in flight
    refine_done: bool = True
    gated_token: Optional[int] = None
    # fault/recovery state machine (runtime/faults.py) — inert without an
    # injector: home mirrors the arrival device, state advances, and the
    # rest stays at its default
    home: int = -1                            # current serving device
    state: str = "new"                        # lifecycle phase (see faults.py)
    finished: bool = False                    # terminal (done or failed)
    epoch: int = 0                            # phase-timer invalidation token
    retries: int = 0                          # cumulative resend budget used
    sent_down: int = 0                        # fresh ids shipped by the cloud
    cloud_served_upto: int = 0                # highest edge_pos served (dedupe)
    last_sent: Optional[tuple] = None         # (tok, seq) for resends
    checkpoint: object = None                 # DecodeCheckpoint mid-migration
    # gateway response cache: the generated ids a cache hit replayed
    # (byte-identical to the original computation — asserted in tests)
    cached_ids: Optional[tuple] = None

    @property
    def uid(self) -> int:
        return self.trace.uid


class EdgeDevice:
    """Serial edge processor feeding a shared uplink."""

    def __init__(self, dev_id: int, *, loop: EventLoop, cost: CostModel,
                 uplink: Wire, server: "CloudServer",
                 bank: Optional[SplitModelBank], mode: str, wire_mode: str,
                 d_r: int, telemetry: Telemetry, numerics_split: int = 1,
                 cell: str = "cell0", cell_index: int = 0):
        self.dev_id = dev_id
        self.numerics_split = numerics_split
        self.loop = loop
        self.cost = cost                    # this cell's cost model (edge hw)
        self.uplink = uplink                # this cell's Wire
        self.server = server
        self.bank = bank
        self.mode = mode
        self.wire_mode = wire_mode
        self.d_r = d_r
        self.telemetry = telemetry
        self.cell = cell                    # topology cell this device lives in
        self.cell_index = cell_index
        self.edge_mp = cost.edge_mp
        self.free_at = 0.0
        self.evicted = False                # set by FaultInjector on churn
        self.injector = None                # FaultInjector when faults are on
        self._local_engine = None
        self._numerics_pending: List[SimRequest] = []
        # flight recorder (simulator swaps in a live tracer when tracing);
        # dev_id is fleet-global, so the track is unique per device
        self.tracer = NULL_TRACER
        self.track = f"edge/{cell}/dev{dev_id}"
        # (t_edge_start, t_edge_done) of recent arrivals — the sampler's
        # queue-depth source (how many requests are waiting or computing)
        self._recent_starts: deque = deque()

    def runner(self, split: int):
        """This cell's view of the bank: the edge half runs at the cell's
        model-axis degree (the cloud degree is fleet-global)."""
        return self.bank.runner(split, edge_mp=self.edge_mp)

    def queue_depth(self, now: float) -> int:
        """Arrivals whose edge compute has not started by ``now`` — the
        device-queue gauge the metrics sampler snapshots."""
        while self._recent_starts and self._recent_starts[0][1] <= now:
            self._recent_starts.popleft()
        return sum(1 for s, _ in self._recent_starts if s > now)

    def on_arrival(self, req: SimRequest) -> None:
        t = req.trace
        t.t_arrival = self.loop.now
        req.home = self.dev_id
        req.state = "edge_compute"
        if self.mode == "split" and self.bank is not None:
            self._numerics_pending.append(req)
        start = max(self.loop.now, self.free_at)
        S = t.prompt_len
        if self.mode == "split":
            dur = self.cost.edge_prefill_s(t.split, S, self.d_r)
        elif self.mode == "edge":
            dur = self.cost.full_prefill_s(S, where="edge")
            dur += sum(self.cost.decode_step_s(1, where="edge")
                       for _ in range(max(req.max_new_tokens - 1, 0)))
        else:                                   # cloud-only: capture + ship
            dur = 0.0
        t.t_edge_start = start
        t.t_edge_done = start + dur
        self.free_at = t.t_edge_done
        self._recent_starts.append((start, t.t_edge_done))
        if self.tracer.enabled:
            self.tracer.async_span(f"req/{self.cell}", "edge_queue", t.uid,
                                   t.t_arrival, start)
            if dur > 0:
                name = "prefill" if self.mode == "split" else "local_infer"
                self.tracer.complete(self.track, name, start, start + dur,
                                     cat="edge", args={"uid": t.uid, "S": S})
        self.loop.schedule_at(t.t_edge_done, lambda: self._edge_done(req),
                              owner=self)

    def _edge_done(self, req: SimRequest) -> None:
        if req.finished:
            return
        t = req.trace
        t.mobile_energy_mj += self.cost.edge_energy_mj(t.edge_compute_s)
        if self.mode == "split" and self.bank is not None and \
                req.payload is None:
            self._compute_edge_batch(req)
        if self.mode == "edge":
            self._finish_local(req)
            return
        get_transport(t.transport).after_edge_prefill(self, req)
        self.send_payload(req, first=True)

    def send_payload(self, req: SimRequest, first: bool = False) -> None:
        """Ship the prefill payload up the cell's wire.  Retries re-enter
        here (``first=False``): the bytes accumulate, the uplink timestamps
        re-stamp, and the phase timer re-arms."""
        if req.finished:
            return
        t = req.trace
        transport = get_transport(t.transport)
        nbytes = transport.prefill_uplink_bytes(self, req)
        t.wire_bytes += nbytes
        if transport.name == "progressive" and self.mode == "split":
            start, done = self._send_progressive(req, nbytes)
        else:
            start, done = self.uplink.transfer(nbytes, self.loop.now,
                                               uid=t.uid, tag="prefill")
            self.loop.schedule_at(done, lambda: self.server.on_payload(req),
                                  owner=self.uplink)
        t.t_uplink_start, t.t_uplink_done = start, done
        t.mobile_energy_mj += self.uplink.transfer_energy_mj(nbytes)
        if first and self.tracer.enabled:
            self.tracer.async_span(f"req/{self.cell}", "uplink_wait", t.uid,
                                   t.t_edge_done, start)
        req.state = "uplink"
        gw = self.server.gateway
        if first and gw is not None and gw.wants_hedge(req):
            gw.arm_hedge(self, req)
        if self.injector is not None:
            self.injector.arm(
                req, lambda: self.server.device_for(req).send_payload(req),
                "payload")

    def _send_progressive(self, req: SimRequest, nbytes: float) -> tuple:
        """Two back-to-back FIFO uplink chunks: the coarse bitplanes plus
        scales first, the refinement planes right behind.  ``on_payload``
        fires at the COARSE landing — the cloud prefill overlaps the
        refinement tail — and the refine landing unfreezes the first
        token.  ``t_uplink_done`` stamps the coarse landing (when the
        cloud can start), keeping the breakdown chain monotone; the tail
        overlaps the cloud_queue/cloud legs."""
        from repro.core import wire_codec

        t = req.trace
        now = self.loop.now
        scale_bytes = t.prompt_len * 4
        code_bytes = max(int(nbytes) - scale_bytes, 0)
        coarse, refine = wire_codec.split_coarse_refine(code_bytes,
                                                        scale_bytes)
        # the two-chunk split costs a second stream header beyond the
        # single-shot payload: count what actually crosses the wire
        t.wire_bytes += (coarse + refine) - float(nbytes)
        start, c_done = self.uplink.transfer(coarse, now, uid=t.uid,
                                             tag="prefill")
        _, r_done = self.uplink.transfer(refine, now, uid=t.uid,
                                         tag="refine")
        req.refine_done = False
        self.loop.schedule_at(c_done, lambda: self.server.on_payload(req),
                              owner=self.uplink)
        self.loop.schedule_at(r_done, lambda: self._refine_landed(req),
                              owner=self.uplink)
        return start, c_done

    def _refine_landed(self, req: SimRequest) -> None:
        if req.finished:
            return
        get_transport("progressive").release_gated(self.server, req)

    def restart_prefill(self, req: SimRequest) -> None:
        """Migration target: redo the edge prefill for a request whose home
        device was evicted mid-compute.  The queue timestamps re-stamp (the
        work really runs twice), so sum(breakdown) == latency still holds."""
        if req.finished or self.evicted:
            return
        t = req.trace
        if self.mode == "split" and self.bank is not None and \
                req.payload is None and req not in self._numerics_pending:
            self._numerics_pending.append(req)
        start = max(self.loop.now, self.free_at)
        S = t.prompt_len
        if self.mode == "split":
            dur = self.cost.edge_prefill_s(t.split, S, self.d_r)
        elif self.mode == "edge":
            dur = self.cost.full_prefill_s(S, where="edge")
            dur += sum(self.cost.decode_step_s(1, where="edge")
                       for _ in range(max(req.max_new_tokens - 1, 0)))
        else:
            dur = 0.0
        t.t_edge_start = start
        t.t_edge_done = start + dur
        self.free_at = t.t_edge_done
        self._recent_starts.append((start, t.t_edge_done))
        req.home = self.dev_id
        req.state = "edge_compute"
        if self.tracer.enabled and dur > 0:
            name = "prefill" if self.mode == "split" else "local_infer"
            self.tracer.complete(self.track, name, start, start + dur,
                                 cat="edge", args={"uid": t.uid, "S": S})
        self.loop.schedule_at(t.t_edge_done, lambda: self._edge_done(req),
                              owner=self)

    def fallback_local(self, req: SimRequest) -> None:
        """Degraded edge-only service for a split request whose cloud half
        is unreachable: run the FULL model on this device."""
        if req.finished or self.evicted:
            return
        t = req.trace
        start = max(self.loop.now, self.free_at)
        dur = self.cost.full_prefill_s(t.prompt_len, where="edge")
        dur += sum(self.cost.decode_step_s(1, where="edge")
                   for _ in range(max(req.max_new_tokens - 1, 0)))
        self.free_at = start + dur
        self._recent_starts.append((start, start + dur))
        req.home = self.dev_id
        req.state = "edge_fallback"
        if self.tracer.enabled:
            self.tracer.complete(self.track, "local_infer", start,
                                 start + dur, cat="edge",
                                 args={"uid": t.uid, "S": t.prompt_len})
        self.loop.schedule_at(start + dur,
                              lambda: self._fallback_done(req, dur),
                              owner=self)

    def _fallback_done(self, req: SimRequest, dur: float) -> None:
        if req.finished:
            return
        t = req.trace
        t.mobile_energy_mj += self.cost.edge_energy_mj(dur)
        if self.bank is not None and req.tokens is not None:
            eng = self._ensure_local_engine()
            req.engine_req = eng.submit(req.tokens,
                                        max_new_tokens=req.max_new_tokens)
            eng.run()
            t.new_tokens = len(req.engine_req.generated)
        else:
            t.new_tokens = req.max_new_tokens
        t.t_first_token = t.t_done = self.loop.now
        t.clamp_chain()
        self.telemetry.record(t)
        self.server.sim_request_done(req)

    def _compute_edge_batch(self, req: SimRequest) -> None:
        """One batched edge_half over every queued arrival sharing this
        request's split + prompt shape; results slice back per request.
        Numerics are time-invariant, so computing a queued request's payload
        at the head request's completion instant is exact."""
        import jax

        # MoE routes all tokens of a batch into one shared expert-capacity
        # pool, so stacking independent requests would change each one's
        # numerics — coalesce only where batch rows are independent
        if self.bank.batch_numerics_ok:
            group = [r for r in self._numerics_pending
                     if r.trace.split == req.trace.split and
                     r.tokens.shape == req.tokens.shape]
        else:
            group = [req]
        runner = self.runner(req.trace.split)
        toks = np.stack([r.tokens for r in group])
        payload, scales, cache0 = runner.edge_half(runner.params, toks)
        for i, r in enumerate(group):
            r.payload = (payload[i:i + 1], scales[i:i + 1],
                         jax.tree.map(lambda a: a[:, i:i + 1], cache0))
            self._numerics_pending.remove(r)
        self.telemetry.counters["edge_numerics_batches"] += 1
        self.telemetry.counters["edge_numerics_requests"] += len(group)
        self.tracer.instant(self.track, "coalesce", self.loop.now,
                            args={"group": len(group),
                                  "split": req.trace.split})

    def _finish_local(self, req: SimRequest) -> None:
        """Mobile-only baseline: everything already ran on the device."""
        t = req.trace
        t.t_uplink_start = t.t_uplink_done = t.t_cloud_start = t.t_edge_done
        t.t_first_token = t.t_cloud_done = t.t_done = t.t_edge_done
        if self.bank is not None:
            eng = self._ensure_local_engine()
            req.engine_req = eng.submit(req.tokens,
                                        max_new_tokens=req.max_new_tokens)
            eng.run()
            t.new_tokens = len(req.engine_req.generated)
        else:
            t.new_tokens = req.max_new_tokens
        self.telemetry.record(t)
        self.server.sim_request_done(req)

    def _ensure_local_engine(self):
        """Mobile-only / fallback runs the same hosted model (split is a
        no-op for numerics when both halves share a device); one engine per
        device, reused across its serial requests.  It lives on the DEVICE:
        run it at the edge degree so local inference never builds the
        cloud's mesh."""
        if self._local_engine is None:
            runner = self.runner(self.numerics_split)
            self._local_engine = runner.make_engine(
                max_batch=1, max_len=self.server.max_len,
                mp=runner.edge_mp)
        return self._local_engine


@dataclass(frozen=True)
class CloudSpec:
    """What a cloud deployment IS (bank, cost model, limits) — as opposed
    to how it is wired into a particular simulation (loop, telemetry,
    wire, callbacks), which stays keyword arguments on
    :class:`CloudServer`.  Frozen: a spec can be shared and compared
    across runs."""
    cost: CostModel
    bank: Optional[SplitModelBank] = None
    mode: str = "split"                       # split | cloud | edge
    d_r: int = 16
    max_concurrent: int = 8                   # slot-pool size per replica
    background_load: Optional[Callable[[float], float]] = None
    engine_seed: int = 0
    max_len: int = 256
    numerics_split: int = 1


class CloudServer:
    """Serial accelerator + slot pool running continuous batching."""

    def __init__(self, spec: CloudSpec, *, loop: EventLoop,
                 telemetry: Telemetry,
                 wire: Optional[Wire] = None,
                 on_done: Optional[Callable[[SimRequest], None]] = None):
        self.spec = spec
        self.numerics_split = spec.numerics_split
        self.loop = loop
        self.cost = spec.cost
        self.bank = spec.bank
        self.mode = spec.mode
        self.d_r = spec.d_r
        self.telemetry = telemetry
        self.max_concurrent = spec.max_concurrent
        self.background_load = spec.background_load or (lambda t: 0.0)
        self.max_len = spec.max_len
        self.engine_seed = spec.engine_seed
        self.on_done = on_done
        self.wire = wire                          # downlink fallback (1 cell)
        self.devices: List[object] = []           # filled by the simulator
        # a FIFO JobQueue is deque-identical; an attached Gateway swaps in
        # its priority queue (runtime/gateway.py)
        self.pending: JobQueue = JobQueue()
        self.stream_ready: deque[SimRequest] = deque()  # rows awaiting a turn
        self.slots: List[Optional[SimRequest]] = [None] * spec.max_concurrent
        self.slot_history: List[tuple] = []       # (uid, slot) admissions
        self._engines: Dict[int, object] = {}     # split -> ServingEngine
        self._virtual_left: Dict[int, int] = {}   # uid -> decode steps left
        self._cloud_results: Dict[int, tuple] = {}  # uid -> (logits, c1, c0)
        self._busy = False
        self._prefill_busy_until = 0.0            # serial accelerator frontier
        self.peak_active = 0
        self.tracer = NULL_TRACER                 # swapped in by the simulator
        self.injector = None                      # FaultInjector when faults on
        self.gateway = None                       # Gateway when a policy is set
        # autoscaled replica count: each replica contributes one
        # max_concurrent slot pool and one accelerator's worth of parallel
        # service capacity (the gateway's autoscaler mutates this)
        self.replicas = 1
        # cloud-outage window: ingress (payloads, rows) is dropped while
        # now < outage_until; work already admitted finishes decoding —
        # the modeled outage is an ingress blackout, not engine surgery
        self.outage_until = float("-inf")

    # -- load signal --------------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(1 for r in self.slots if r is not None)

    @property
    def num_decoding(self) -> int:
        """Slots decoding locally (cache handoff); token-streaming slots
        (streamed/progressive) wait for rows from the edge and take no
        batched decode turns."""
        return sum(1 for r in self.slots
                   if r is not None and
                   not get_transport(r.trace.transport).streams_tokens)

    def current_load(self, now: float) -> float:
        """Combined congestion the mobile observes when it pings the server:
        external tenants (background) plus this fleet's own occupancy.
        During a cloud outage the ping itself fails — the controller reads
        the ceiling and routes work edge-heavy."""
        if now < self.outage_until:
            return 0.99
        bg = min(max(self.background_load(now), 0.0), 0.99)
        # the denominator is the LIVE slot pool: an autoscaled replica
        # coming online visibly drops the load the controllers observe
        occ = self.num_active / len(self.slots)
        return min(1.0 - (1.0 - bg) * (1.0 - occ), 0.99)

    def device_for(self, req: SimRequest) -> Optional[object]:
        """The device currently serving ``req`` — its migration home when
        the fault layer re-homed it, else the arrival device."""
        if not self.devices:
            return None
        return self.devices[req.home if req.home >= 0 else req.trace.device]

    def wire_for(self, req: SimRequest) -> Optional[Wire]:
        """The Wire serving ``req``'s cell (responses go back down the same
        link the request came up — per-cell downlink contention)."""
        dev = self.device_for(req)
        return dev.uplink if dev is not None else self.wire

    # -- request flow -------------------------------------------------------
    def on_payload(self, req: SimRequest) -> None:
        if req.finished:
            return
        if self.injector is not None:
            if self.loop.now < self.outage_until:
                self.telemetry.counters["fault_outage_dropped_payloads"] += 1
                if self.gateway is not None:
                    # the breaker counts dropped ingress as a health signal
                    self.gateway.note_dropped_payload(req.trace.cell)
                return
            if req.slot >= 0 or req in self.pending:
                # a spurious retry: the original made it after all
                self.telemetry.counters["fault_duplicate_payloads"] += 1
                return
        elif self.gateway is not None and \
                (req.slot >= 0 or req in self.pending):
            # the losing copy of a hedged send
            self.telemetry.counters["gateway_duplicate_payloads"] += 1
            return
        if self.gateway is not None and not self.gateway.admit(req):
            return            # shed, or served from the response cache
        req.state = "cloud"
        self.pending.append(req)
        self._kick()

    def on_stream_row(self, req: SimRequest) -> None:
        """A streamed decode row arrived over the uplink."""
        if req.finished:
            return
        if self.injector is not None:
            if self.loop.now < self.outage_until:
                self.telemetry.counters["fault_outage_dropped_rows"] += 1
                return
            if req in self.stream_ready:
                self.telemetry.counters["fault_duplicate_stream_rows"] += 1
                return
        self.stream_ready.append(req)
        self._kick()

    def _kick(self) -> None:
        if not self._busy:
            self._busy = True
            self.loop.schedule(0.0, self._service)

    def _engine(self, split: int):
        if self.bank is None:
            return None
        if self.mode != "split":
            split = self.numerics_split   # cloud-only runs one hosted model
        if split not in self._engines:
            self._engines[split] = self.bank.runner(split).make_engine(
                max_batch=self.max_concurrent, max_len=self.max_len,
                seed=self.engine_seed)
        return self._engines[split]

    def _free_slot(self) -> int:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return -1

    def _service(self) -> None:
        now = self.loop.now
        # admit every pending prefill the slot pool can hold in one service
        # turn; durations stay serial (cumulative past the busy frontier),
        # so the accelerator never runs two prefills — or a prefill and a
        # decode — at once, exactly like one-at-a-time admission
        start = max(now, self._prefill_busy_until)
        admitted = 0
        while self.pending and now >= self.outage_until:
            slot = self._free_slot()
            if slot < 0:
                break
            if self.gateway is not None and not self.gateway.may_start(
                    self.pending.peek(),
                    sum(1 for s in self.slots if s is None)):
                # head is batch-class and would eat a reserved slot; the
                # priority queue guarantees nothing interactive is behind it
                break
            req = self.pending.popleft()
            start = self._admit(req, slot, start)
            admitted += 1
        if admitted:
            self._prefill_busy_until = start
            if admitted > 1:
                self.telemetry.counters["cloud_prefill_bursts"] += 1
            return
        if now < self._prefill_busy_until:
            return                      # mid-burst: next _prefill_done rearms
        if self.stream_ready:
            self._stream_turn(now)
            return
        if self.num_decoding > 0:
            self._decode_step(now)
            return
        self._busy = False

    def _admit(self, req: SimRequest, slot: int, start: float) -> float:
        """Place ``req`` in ``slot`` with its prefill starting at ``start``;
        returns the prefill completion time (the next admission's start)."""
        t = req.trace
        t.t_cloud_start = start
        load = min(max(self.background_load(start), 0.0), 0.99)
        S = t.prompt_len
        if self.mode == "split":
            dur = self.cost.cloud_prefill_s(t.split, S, self.d_r, load)
        else:
            dur = self.cost.full_prefill_s(S, where="cloud", load=load)
        req.slot = slot
        self.slots[slot] = req
        self.slot_history.append((t.uid, slot))
        self.peak_active = max(self.peak_active, self.num_active)
        if self.injector is not None:
            self.injector.ack(req)          # payload made it: cancel retries
        if self.tracer.enabled:
            self.tracer.async_span(f"req/{t.cell}", "cloud_queue", t.uid,
                                   t.t_uplink_done, start)
            self.tracer.complete("cloud/accel", "prefill", start, start + dur,
                                 cat="cloud", args={"uid": t.uid,
                                                    "split": t.split,
                                                    "slot": slot})
        self.loop.schedule_at(start + dur, lambda: self._prefill_done(req))
        # with R autoscaled replicas, R prefills run concurrently in
        # aggregate: each request still takes its full duration, but the
        # serial frontier the NEXT admission queues behind advances at R
        # times the rate (replicas == 1 reduces to the serial accelerator)
        return start + dur / self.replicas

    def _cloud_numerics(self, req: SimRequest) -> tuple:
        """(last logits row, cache1 slice, cache0) for ``req``; the first
        call of a burst batches the cloud half over every in-flight payload
        of the same split (admitted or still pending) in one jitted call."""
        import jax
        import jax.numpy as jnp

        if req.uid not in self._cloud_results:
            split = req.trace.split
            group = [req]
            if self.bank.batch_numerics_ok:   # see _compute_edge_batch
                group += [
                    r for r in list(self.slots) + list(self.pending)
                    if r is not None and r is not req
                    and r.payload is not None and r.trace.split == split
                    and r.payload[0].shape == req.payload[0].shape]
            runner = self.bank.runner(split)
            payload = jnp.concatenate([r.payload[0] for r in group])
            scales = jnp.concatenate([r.payload[1] for r in group])
            logits, cache1 = runner.cloud_half(runner.params, payload, scales)
            for i, r in enumerate(group):
                self._cloud_results[r.uid] = (
                    logits[i], jax.tree.map(lambda a: a[:, i:i + 1], cache1),
                    r.payload[2])
                r.payload = None
            self.telemetry.counters["cloud_numerics_batches"] += 1
            self.telemetry.counters["cloud_numerics_prefills"] += len(group)
        return self._cloud_results.pop(req.uid)

    def _prefill_done(self, req: SimRequest) -> None:
        if not req.finished:       # failed mid-prefill: drop the result
            get_transport(req.trace.transport).start_cloud_decode(self, req)
        self.loop.schedule(0.0, self._service)

    def _stream_turn(self, now: float) -> None:
        """Serve every arrived streamed row in one serial-accelerator turn:
        rows of the same split batch into one charged step; numerics run
        when the turn completes."""
        batch = list(self.stream_ready)
        self.stream_ready.clear()
        load = min(max(self.background_load(now), 0.0), 0.99)
        dur = 0.0
        for split in sorted({r.trace.split for r in batch}):
            k = sum(1 for r in batch if r.trace.split == split)
            dur += self.cost.cloud_decode_step_s(split, self.d_r, k, load)
        dur /= self.replicas
        self.telemetry.counters["stream_cloud_turns"] += 1
        self.telemetry.counters["stream_rows"] += len(batch)
        self.tracer.complete("cloud/accel", "stream_turn", now, now + dur,
                             cat="cloud", args={"rows": len(batch)})
        self.loop.schedule(dur, lambda: self._stream_turn_done(batch))

    def _stream_turn_done(self, batch: List[SimRequest]) -> None:
        # progressive inherits the streamed row service unchanged (the
        # coarse/refine choreography only touches the prefill upload), so
        # one singleton serves mixed batches without reordering the turn
        get_transport("streamed").serve_rows(self, batch)
        self.loop.schedule(0.0, self._service)

    def _decode_step(self, now: float) -> None:
        batch = self.num_decoding
        load = min(max(self.background_load(now), 0.0), 0.99)
        # replicas split the decode batch: each runs its share in parallel
        dur = self.cost.decode_step_s(-(-batch // self.replicas),
                                      where="cloud", load=load)
        self.tracer.complete("cloud/accel", "decode_turn", now, now + dur,
                             cat="cloud", args={"batch": batch})
        self.loop.schedule(dur, self._decode_done)

    def _decode_done(self) -> None:
        handoff = [r for r in self.slots
                   if r is not None and
                   not get_transport(r.trace.transport).streams_tokens]
        if self.bank is not None:
            stepped = set()
            for req in handoff:
                eng = self._engine(req.trace.split)
                if id(eng) not in stepped:
                    eng.step()
                    stepped.add(id(eng))
            for req in handoff:
                if req.engine_req.done:
                    self._complete(req)
        else:
            for req in handoff:
                left = self._virtual_left.get(req.uid)
                if left is None:
                    # replicas > 1: the aggregate prefill frontier advances
                    # faster than each request's own prefill, so a slot can
                    # sit in the pool before its decode state exists — it
                    # joins the batch on the turn after its prefill lands
                    continue
                self._virtual_left[req.uid] = left - 1
                if left <= 1:
                    self._complete(req)
        self.loop.schedule(0.0, self._service)

    def _complete(self, req: SimRequest) -> None:
        """Cloud-side decode finished (cache-handoff / cloud-only): free the
        slot and ship the whole sampled-id batch down the Wire; the request
        is delivered — and recorded — when the downlink drains."""
        if req.finished:
            return
        t = req.trace
        t.t_cloud_done = self.loop.now
        if req.engine_req is not None:
            t.new_tokens = len(req.engine_req.generated)
        else:
            t.new_tokens = req.max_new_tokens
        if req.slot >= 0:
            self.release_slot(req, self.loop.now)
        self._ship_ids(req)

    def _ship_ids(self, req: SimRequest) -> None:
        """Ship the whole id batch down; retries re-enter here."""
        if req.finished:
            return
        t = req.trace
        wire = self.wire_for(req)
        if wire is None:                    # no modeled downlink: instant
            self._deliver(req)
            return
        nbytes = TOKEN_BYTES * t.new_tokens
        t.downlink_bytes += nbytes
        start, done = wire.transfer_down(nbytes, self.loop.now, uid=t.uid,
                                         tag="ids")
        t.mobile_energy_mj += wire.downlink_energy_mj(nbytes)
        req.state = "downlink"
        self.loop.schedule_at(done, lambda: self._deliver(req), owner=wire)
        if self.injector is not None:
            self.injector.arm(req, lambda: self._ship_ids(req), "ids")

    def release_slot(self, req: SimRequest,
                     now: Optional[float] = None) -> None:
        """Free ``req``'s engine slot, closing its residency span (admission
        prefill start -> release) on the slot's trace track."""
        now = self.loop.now if now is None else now
        slot = req.slot
        self.slots[slot] = None
        req.slot = -1
        if self.tracer.enabled:
            t = req.trace
            self.tracer.complete(f"cloud/slot{slot}", f"u{t.uid}",
                                 t.t_cloud_start, now, cat="slot",
                                 args={"uid": t.uid, "split": t.split,
                                       "transport": t.transport})

    def _deliver(self, req: SimRequest) -> None:
        if req.finished:
            return
        t = req.trace
        t.t_done = self.loop.now
        # batch return: the mobile sees its first token when the whole id
        # shipment lands — the same observation point streamed TTFT uses
        t.t_first_token = t.t_done
        t.clamp_chain()
        self.telemetry.record(t)
        self.sim_request_done(req)

    def sim_request_done(self, req: SimRequest) -> None:
        if req.finished:
            return
        req.finished = True
        req.state = "done"
        if self.gateway is not None:
            # every terminal outcome funnels through here: feed the
            # breaker/EWMA/cache health signals
            self.gateway.note_outcome(req)
        if self.on_done is not None:
            self.on_done(req)
