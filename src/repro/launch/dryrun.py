import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is the multi-pod dry-run driver:
# for every (architecture x input shape x mesh) it AOT-lowers the real
# train/prefill/serve step with production shardings, compiles, and records
# memory/cost/roofline analysis.  No arrays are ever allocated at full scale
# (ShapeDtypeStruct in, compiled artifact out).
import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.models.transformer as _tfm

from repro.configs import INPUT_SHAPES, get_config, supports_shape
from repro.configs.all import ASSIGNED
from repro.core import costs
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models import model as M
from repro.models.parallel import make_context
from repro.training.optimizer import AdamWConfig, adamw_init, cosine_schedule
from repro.training.train_loop import make_train_step


def _is_p(x):
    return isinstance(x, P)


def shardings_of(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_p)


def params_abstract(built):
    """(param ShapeDtypeStructs, PartitionSpec tree) without allocation."""
    captured = {}

    def initf(key):
        p, s = M.init_model(key, built)
        captured["s"] = s
        return p

    sds = jax.eval_shape(initf, jax.random.key(0))
    return sds, captured["s"]


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               butterfly_layer: Optional[int] = None, d_r: int = 0,
               donate: bool = True, extra_note: str = "",
               unroll: Optional[bool] = None):
    """Lower+compile one (arch x shape x mesh). Returns (compiled, meta).

    ``unroll`` — fully unroll segment scans so cost_analysis is exact (XLA
    counts while bodies once).  Default: unroll on the single-pod mesh (the
    roofline table is single-pod), rolled on multi-pod (compile-proof only).
    """
    _tfm.SCAN_UNROLL = (not multi_pod) if unroll is None else unroll
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if butterfly_layer is not None:
        cfg = cfg.with_butterfly(butterfly_layer, d_r or max(64, cfg.d_model // 16))
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    long_mode = shape_name == "long_500k"
    mesh = make_production_mesh(multi_pod=multi_pod)
    pctx = make_context(mesh)
    built = M.build(cfg, long_mode=long_mode)

    p_sds, p_specs = params_abstract(built)
    p_sh = shardings_of(mesh, p_specs)
    batch_sds, batch_specs = M.input_specs(built, shape, pctx)
    batch_sh = shardings_of(mesh, batch_specs)

    t0 = time.time()
    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, p_sds)
        opt_specs = {"mu": p_specs, "nu": p_specs, "step": P()}
        opt_sh = shardings_of(mesh, opt_specs)
        step_fn = make_train_step(
            built, AdamWConfig(lr=cosine_schedule(3e-4, 100, 10000)), pctx)
        jfn = jax.jit(step_fn,
                      in_shardings=(p_sh, opt_sh, batch_sh),
                      out_shardings=(p_sh, opt_sh, None),
                      donate_argnums=(0, 1) if donate else ())
        lowered = jfn.lower(p_sds, opt_sds, batch_sds)
        model_flops = costs.model_flops_train(cfg, shape.global_batch * shape.seq_len)
    elif shape.kind == "prefill":

        def prefill_fn(params, batch):
            return M.forward_prefill(params, built, batch, pctx)

        out_sh = None
        if os.environ.get("REPRO_PREFILL_CACHE_SHARDED", "0") == "1":
            # perf iteration (EXPERIMENTS.md section Perf): without explicit
            # out_shardings XLA replicates the produced KV caches across the
            # mesh (TB-scale all-gathers); pin them batch->data, seq->model
            cache_specs = [_tfm.stage_cache_spec(
                list(segs), pctx.batch_spec_axes(), "model")
                for segs in built.stages]
            out_sh = (None, shardings_of(mesh, cache_specs))
        jfn = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh),
                      out_shardings=out_sh)
        lowered = jfn.lower(p_sds, batch_sds)
        model_flops = 2.0 * costs.param_count(cfg, active_only=True) * \
            shape.global_batch * shape.seq_len
    else:  # decode
        seq_axis = ("data", "model") if shape.global_batch == 1 else "model"
        cache_sds, cache_specs = M.decode_state_specs(built, shape, pctx,
                                                      seq_axis=seq_axis)
        cache_sh = shardings_of(mesh, cache_specs)

        def decode_fn(params, tokens, caches, pos):
            return M.forward_decode(params, built, tokens, caches, pos, pctx)

        tok_sh = shardings_of(mesh, batch_specs)["tokens"]
        jfn = jax.jit(decode_fn,
                      in_shardings=(p_sh, tok_sh, cache_sh, None),
                      out_shardings=(None, cache_sh),
                      donate_argnums=(2,) if donate else ())
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jfn.lower(p_sds, batch_sds["tokens"], cache_sds, pos_sds)
        model_flops = costs.model_flops_decode(cfg, shape.global_batch)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh_chips(mesh),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops": model_flops,
        "butterfly": None if cfg.butterfly is None else
            {"layer": cfg.butterfly.layer, "d_r": cfg.butterfly.d_r},
        "unrolled": _tfm.SCAN_UNROLL,
        "note": extra_note,
    }
    return compiled, meta


def _corrected_costs(arch, shape_name, multi_pod, butterfly_layer, d_r):
    """Two-point scan correction for stacks too deep to unroll within the
    compile budget: lower with unroll=1 and unroll=2; the delta isolates one
    extra per-iteration body per segment (+ odd-length remainders), from
    which exact totals follow under a per-layer-uniform cost assumption
    within each segment (exact for single-segment stacks; DESIGN.md 9.5).

    m1 = out + sum_s L_s*u ;  m2 = out + sum_s (2 + r_s%2)*L_s*u
    => u = (m2-m1) / sum_s (1 + r_s%2)*L_s
    total = m1 + sum_s (r_s-1)*L_s*u
    """
    from repro.configs import get_config as _gc
    cfg = _gc(arch)
    if butterfly_layer is not None:
        cfg = cfg.with_butterfly(butterfly_layer, d_r or 64)
    built = M.build(cfg, long_mode=shape_name == "long_500k")
    segs = [s for stage in built.stages for s in stage]
    denom = sum((1 + s.repeats % 2) * len(s.unit) for s in segs if s.repeats > 1)
    numer = sum((s.repeats - 1) * len(s.unit) for s in segs)
    c1, meta1 = lower_pair(arch, shape_name, multi_pod, butterfly_layer, d_r,
                           unroll=1)
    c2, _ = lower_pair(arch, shape_name, multi_pod, butterfly_layer, d_r,
                       unroll=2)
    rep1 = roofline.analyze(arch, shape_name, meta1["mesh"], meta1["chips"],
                            c1, meta1["model_flops"])
    rep2 = roofline.analyze(arch, shape_name, meta1["mesh"], meta1["chips"],
                            c2, meta1["model_flops"])

    def corr(a, b):
        return a + (b - a) / max(denom, 1) * numer

    rep1.flops_per_device = corr(rep1.flops_per_device, rep2.flops_per_device)
    rep1.bytes_per_device = corr(rep1.bytes_per_device, rep2.bytes_per_device)
    rep1.collectives = {k: int(corr(rep1.collectives[k], rep2.collectives[k]))
                        for k in rep1.collectives}
    rep1.collective_bytes_per_device = sum(rep1.collectives.values())
    rep1.compute_s = rep1.flops_per_device / roofline.PEAK_FLOPS
    rep1.memory_s = rep1.bytes_per_device / roofline.HBM_BW
    rep1.collective_s = rep1.collective_bytes_per_device / roofline.LINK_BW
    terms = {"compute": rep1.compute_s, "memory": rep1.memory_s,
             "collective": rep1.collective_s}
    rep1.bottleneck = max(terms, key=terms.get)
    total = rep1.flops_per_device * meta1["chips"]
    rep1.useful_ratio = meta1["model_flops"] / total if total else 0.0
    rep1.note = "two-point scan correction (unroll 1 vs 2)"
    meta1["unrolled"] = "corrected"
    return rep1, meta1


def run_pair(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             butterfly_layer: Optional[int] = None, d_r: int = 0,
             tag: str = "", unroll: Optional[bool] = None,
             correct: bool = False) -> dict:
    if correct:
        ok, why = supports_shape(get_config(arch), INPUT_SHAPES[shape_name])
        if not ok:
            mesh_name = "2x16x16" if multi_pod else "16x16"
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "skipped": why}
            print(f"SKIP  {arch:28s} {shape_name:12s} {mesh_name:8s} {why}")
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}_{shape_name}_{mesh_name.replace('x','-')}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
            return rec
        rep, meta = _corrected_costs(arch, shape_name, multi_pod,
                                     butterfly_layer, d_r)
        mesh_name = meta["mesh"]
        rec = {**meta, **roofline.to_dict(rep)}
        print(f"OK*   {arch:28s} {shape_name:12s} {mesh_name:8s} "
              f"compute={rep.compute_s*1e3:8.2f}ms memory={rep.memory_s*1e3:8.2f}ms "
              f"coll={rep.collective_s*1e3:8.2f}ms bottleneck={rep.bottleneck:10s} "
              f"useful={rep.useful_ratio:5.2f} (scan-corrected)")
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"{arch}_{shape_name}_{mesh_name.replace('x','-')}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=float)
        return rec
    compiled, meta = lower_pair(arch, shape_name, multi_pod,
                                butterfly_layer, d_r, unroll=unroll)
    mesh_name = meta.get("mesh", "2x16x16" if multi_pod else "16x16")
    if compiled is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, **meta}
        print(f"SKIP  {arch:28s} {shape_name:12s} {mesh_name:8s} {meta['skipped']}")
    else:
        rep = roofline.analyze(arch, shape_name, mesh_name, meta["chips"],
                               compiled, meta["model_flops"])
        rec = {**meta, **roofline.to_dict(rep)}
        mem = rec.get("memory_analysis", {})
        peak = mem.get("peak_memory_in_bytes") or (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0))
        print(f"OK    {arch:28s} {shape_name:12s} {mesh_name:8s} "
              f"compute={rep.compute_s*1e3:8.2f}ms memory={rep.memory_s*1e3:8.2f}ms "
              f"coll={rep.collective_s*1e3:8.2f}ms bottleneck={rep.bottleneck:10s} "
              f"useful={rep.useful_ratio:5.2f} peakmem={peak/1e9:6.2f}GB "
              f"compile={meta['compile_s']}s")
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = f"{arch}_{shape_name}_{mesh_name.replace('x','-')}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod AOT dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all arch x shape")
    ap.add_argument("--butterfly-layer", type=int, default=None)
    ap.add_argument("--d-r", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--correct-scan", action="store_true",
                    help="two-point scan correction instead of full unroll")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_pair(arch, shape, mp, args.out,
                                            args.butterfly_layer, args.d_r,
                                            tag=args.tag,
                                            correct=args.correct_scan))
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"FAIL  {arch:28s} {shape:12s} "
                          f"{'2x16x16' if mp else '16x16':8s} "
                          f"{type(e).__name__}: {e}")
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": f"{type(e).__name__}: {e}"})
    n_ok = sum(1 for r in results if "compute_s" in r)
    n_skip = sum(1 for r in results if "skipped" in r)
    n_fail = len(results) - n_ok - n_skip
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
