"""Perf pair 3 (most representative of the paper's technique): the split
pipeline on the multi-pod mesh, measuring the bytes that actually cross the
pod boundary (collective-permute payloads in the compiled HLO) for the three
wire modes:

  raw      prior-art collaborative intelligence (ship the activation)
  reduced  butterfly reduction only (channel bottleneck, bf16)
  int8     the paper: reduction + 8-bit wire

Run: python experiments/perf_pipeline.py [--arch xlstm-125m]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.roofline import LINK_BW, collective_bytes
from repro.models import model as M
from repro.launch.dryrun import params_abstract, shardings_of


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--num-microbatches", type=int, default=16)
    ap.add_argument("--layer", type=int, default=None)
    ap.add_argument("--d-r", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.serving.pipeline import make_split_pipeline
    base = get_config(args.arch)
    layer = args.layer or max(1, base.num_layers // 4)
    d_r = args.d_r or max(16, base.d_model // 64)
    cfg = base.with_butterfly(layer, d_r)
    built = M.build(cfg)
    mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))

    p_sds, _ = params_abstract(built)
    B = args.num_microbatches * args.microbatch
    tok_sds = jax.ShapeDtypeStruct((B, args.seq), jnp.int32)

    results = {}
    for mode in ("raw", "reduced", "int8"):
        pipe = make_split_pipeline(built, mesh, args.num_microbatches,
                                   args.seq, args.microbatch, wire_mode=mode)
        t0 = time.time()
        compiled = jax.jit(pipe).lower(p_sds, tok_sds).compile()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        perm = coll["collective-permute"]
        results[mode] = {
            "collective_permute_bytes": perm,
            "all_collectives": coll,
            "inter_pod_s": perm / LINK_BW,
            "compile_s": round(time.time() - t0, 1),
        }
        print(f"{mode:8s} collective-permute={perm/1e6:8.2f}MB "
              f"inter-pod={perm/LINK_BW*1e3:7.3f}ms "
              f"(compile {results[mode]['compile_s']}s)")

    raw = results["raw"]["collective_permute_bytes"]
    for mode in ("reduced", "int8"):
        r = results[mode]["collective_permute_bytes"]
        print(f"{mode}: {raw / r:.1f}x fewer inter-pod bytes than raw")
    os.makedirs(args.out, exist_ok=True)
    fn = os.path.join(args.out, f"pipeline_{args.arch}_wire_modes.json")
    with open(fn, "w") as f:
        json.dump({"arch": args.arch, "seq": args.seq, "layer": layer,
                   "d_r": d_r, "microbatch": args.microbatch,
                   "num_microbatches": args.num_microbatches,
                   "results": results}, f, indent=1)
    print("wrote", fn)


if __name__ == "__main__":
    main()
