"""Workload-spec API (DESIGN.md section 17): the WorkloadSpec grammar, the
legacy-field deprecation shim (old-style configs build the identical
arrival list and telemetry), the arrival builders, the CloudSpec
constructor diet, and the audited `repro.runtime` public surface."""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.runtime.actors import CloudServer, CloudSpec
from repro.runtime.clock import EventLoop
from repro.runtime.simulator import (SimConfig, Simulation, WorkloadSpec,
                                     build_arrivals, diurnal_arrivals,
                                     flash_arrivals, pareto_arrivals,
                                     record_arrivals, run_sim,
                                     trace_arrivals)
from repro.runtime.split_exec import CostModel
from repro.runtime.telemetry import Telemetry


def small_cfg(layers=4):
    return dataclasses.replace(get_config("qwen3-8b").reduced(),
                               num_layers=layers)


def timing_cfg(**kw):
    defaults = dict(cfg=small_cfg(), mode="split", wire_mode="int8",
                    network="3g", num_devices=4, num_requests=16,
                    arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                    d_r=16, numerics=False, seed=0)
    defaults.update(kw)
    return SimConfig(**defaults)


# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------


def test_workload_parse_grammar():
    w = WorkloadSpec.parse("pareto:alpha=1.5,rate=20,n=100000,"
                           "interactive=0.25,prompt_len=16")
    assert w.kind == "pareto" and w.alpha == 1.5 and w.rate == 20.0
    assert w.n == 100000 and w.interactive == 0.25 and w.prompt_len == 16
    f = WorkloadSpec.parse("flash:rate=10,n=1000,at=0.2,dur=0.3,burst=20")
    assert f.kind == "flash" and f.at == 0.2 and f.dur == 0.3 and \
        f.burst == 20.0
    d = WorkloadSpec.parse("diurnal:rate=20,n=500,period=2.0,depth=0.8")
    assert d.kind == "diurnal" and d.period_s == 2.0 and d.depth == 0.8
    assert WorkloadSpec.parse("poisson:rate=20,n=16").kind == "poisson"


def test_workload_parse_rejects_garbage():
    with pytest.raises(ValueError):
        WorkloadSpec.parse("poisson:rate=20,bogus=1")
    with pytest.raises(ValueError):
        WorkloadSpec.parse("poisson:rate")          # no '='
    with pytest.raises(AssertionError):
        WorkloadSpec.parse("lognormal:rate=20")     # unknown kind
    with pytest.raises(AssertionError):
        WorkloadSpec(kind="pareto", alpha=0.9)      # infinite-mean tail
    with pytest.raises(AssertionError):
        WorkloadSpec(interactive=1.5)


# ---------------------------------------------------------------------------
# legacy shim: old-style config == workload spec, byte for byte
# ---------------------------------------------------------------------------


def test_legacy_fields_equal_workload_spec():
    legacy = timing_cfg()
    spec = timing_cfg(workload="poisson:rate=20,n=16,prompt_len=32")
    a, b = Simulation(legacy), Simulation(spec)
    assert [dataclasses.astuple(x) for x in a.arrivals] == \
        [dataclasses.astuple(x) for x in b.arrivals]
    assert a.run().to_json() == b.run().to_json()


def test_workload_overrides_legacy_fields():
    sim = Simulation(timing_cfg(num_requests=4, arrival_rate=5.0,
                                prompt_len=8,
                                workload="poisson:rate=20,n=16,"
                                         "prompt_len=32"))
    assert len(sim.arrivals) == 16
    assert sim.sim_cfg.arrival_rate == 20.0 and sim.sim_cfg.prompt_len == 32
    # equivalent to the plain legacy run with the spec's values
    assert sim.run().to_json() == run_sim(timing_cfg()).to_json()


def test_class_split_never_perturbs_timing():
    # same kind/rate/n with and without a class split: identical arrival
    # times and prompts, only the slo labels differ
    kw = dict(num_devices=4, prompt_len=8, vocab_size=64, seed=3)
    plain = build_arrivals(WorkloadSpec(rate=20.0, n=32), **kw)
    classed = build_arrivals(WorkloadSpec(rate=20.0, n=32,
                                          interactive=0.5), **kw)
    assert [a.t for a in plain] == [a.t for a in classed]
    for a, b in zip(plain, classed):
        assert np.array_equal(a.tokens, b.tokens)
    assert {a.slo for a in plain} == {"interactive"}
    assert {a.slo for a in classed} == {"interactive", "batch"}


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def test_pareto_mean_matches_rate():
    arr = pareto_arrivals(num_devices=1, num_requests=4000,
                          arrival_rate=10.0, prompt_len=8, alpha=2.5,
                          seed=0)
    gaps = np.diff([0.0] + [a.t for a in arr])
    assert abs(gaps.mean() - 0.1) < 0.02       # mean gap ~ 1/rate
    # heavy tail: the max gap dwarfs the exponential's typical extremes
    assert gaps.max() > 5 * gaps.mean()


def test_diurnal_rate_swings():
    arr = diurnal_arrivals(num_devices=1, num_requests=2000,
                           arrival_rate=50.0, prompt_len=8, period_s=2.0,
                           depth=0.9, seed=0)
    ts = np.array([a.t for a in arr])
    # peak half-cycles are denser than trough half-cycles
    peak = sum(1 for t in ts if (t % 2.0) < 0.5 or (t % 2.0) > 1.5)
    trough = sum(1 for t in ts if 0.5 <= (t % 2.0) <= 1.5)
    assert peak > 2 * trough


def test_flash_crowd_burst_density():
    arr = flash_arrivals(num_devices=2, num_requests=2000,
                         arrival_rate=10.0, prompt_len=8, at=1.0, dur=1.0,
                         burst=10.0, seed=0)
    ts = [a.t for a in arr]
    inside = sum(1 for t in ts if 1.0 <= t < 2.0)
    before = sum(1 for t in ts if 0.0 <= t < 1.0)
    assert inside > 4 * max(before, 1)


def test_builders_are_deterministic_and_device_namespaced():
    kw = dict(num_devices=3, num_requests=30, arrival_rate=10.0,
              prompt_len=8, alpha=1.5, seed=7)
    a, b = pareto_arrivals(**kw), pareto_arrivals(**kw)
    assert [x.t for x in a] == [x.t for x in b]
    # device_offset shifts the streams (independent per-cell arrivals)
    c = pareto_arrivals(**dict(kw, device_offset=3))
    assert [x.t for x in a] != [x.t for x in c]
    assert {x.device for x in c} == {3, 4, 5}


def test_trace_v3_roundtrip_and_v2_legacy(tmp_path):
    arr = build_arrivals(
        WorkloadSpec(kind="pareto", rate=10.0, n=12, interactive=0.5),
        num_devices=2, prompt_len=4, vocab_size=32, seed=1)
    path = str(tmp_path / "t.jsonl")
    record_arrivals(arr, path)
    back = trace_arrivals(path)
    assert [x.slo for x in arr] == [x.slo for x in back]
    assert [x.t for x in arr] == [x.t for x in back]
    assert [x.device for x in arr] == [x.device for x in back]
    for a, b in zip(arr, back):
        assert np.array_equal(a.tokens, b.tokens)
    # a v2 trace (no slo key) replays as all-interactive
    legacy = str(tmp_path / "v2.jsonl")
    with open(legacy, "w") as f:
        f.write(json.dumps({"format": "arrival-trace-v2", "n": 1}) + "\n")
        f.write(json.dumps({"cell": 0, "device": 0, "t": 0.5,
                            "tokens": None}) + "\n")
    old = trace_arrivals(legacy)
    assert old[0].slo == "interactive" and old[0].t == 0.5


# ---------------------------------------------------------------------------
# CloudSpec constructor diet
# ---------------------------------------------------------------------------


def test_cloud_spec_is_frozen_and_wires():
    from repro.core.profiler import GTX_1080TI, JETSON_TX2
    cost = CostModel(small_cfg(), JETSON_TX2, GTX_1080TI)
    spec = CloudSpec(cost=cost, mode="split", max_concurrent=2, max_len=16)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.max_concurrent = 4
    srv = CloudServer(spec, loop=EventLoop(), telemetry=Telemetry())
    assert srv.spec is spec and srv.max_concurrent == 2
    assert len(srv.slots) == 2 and srv.replicas == 1
    assert srv.gateway is None and len(srv.pending) == 0


# ---------------------------------------------------------------------------
# public API audit
# ---------------------------------------------------------------------------


def test_runtime_all_imports_cleanly():
    import repro.runtime as rt
    for name in rt.__all__:
        assert getattr(rt, name, None) is not None, \
            f"__all__ exports {name} but it does not resolve"
    assert len(set(rt.__all__)) == len(rt.__all__), "duplicate exports"


def test_runtime_all_matches_design_doc():
    import repro.runtime as rt
    doc = open("DESIGN.md").read()
    marker = "```text runtime-api\n"
    assert marker in doc, "DESIGN.md lost the runtime-api surface block"
    block = doc.split(marker, 1)[1].split("```", 1)[0]
    documented = block.split()
    assert sorted(documented) == sorted(rt.__all__), \
        "DESIGN.md section 17 surface drifted from repro.runtime.__all__"
