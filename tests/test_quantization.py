"""Property-based tests (hypothesis) for the int8 wire codec — the invariants
the paper's wire format relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property suites need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (dequantize, fake_quant, pack_int4,
                                     quantize, unpack_int4, wire_bytes)

arrays = st.integers(1, 7).flatmap(
    lambda rows: st.integers(2, 33).flatmap(
        lambda cols: st.lists(
            st.floats(-1e4, 1e4, allow_nan=False, width=32),
            min_size=rows * cols, max_size=rows * cols,
        ).map(lambda v: np.asarray(v, np.float32).reshape(rows, cols))))


@settings(max_examples=40, deadline=None)
@given(arrays, st.sampled_from([4, 8, 16]))
def test_roundtrip_error_bounded(x, bits):
    codes, scale = quantize(jnp.asarray(x), bits)
    back = np.asarray(dequantize(codes, scale))
    # absolute error per row bounded by scale/2 (+eps for f32 rounding)
    err = np.abs(back - x)
    bound = np.asarray(scale) * 0.5 + 1e-5 * np.abs(x) + 1e-6
    assert np.all(err <= bound + 1e-6)


@settings(max_examples=40, deadline=None)
@given(arrays)
def test_codes_range_int8(x):
    codes, _ = quantize(jnp.asarray(x), 8)
    c = np.asarray(codes, np.int32)
    assert c.min() >= -128 and c.max() <= 127
    assert codes.dtype == jnp.int8


@settings(max_examples=25, deadline=None)
@given(arrays)
def test_scale_invariance(x):
    """quantize(a*x) has codes equal to quantize(x) for power-of-two a
    (symmetric absmax; rows below the 1e-8 scale floor are excluded)."""
    from hypothesis import assume
    assume(np.all(np.max(np.abs(x), axis=-1) > 1e-3))
    c1, s1 = quantize(jnp.asarray(x), 8)
    c2, s2 = quantize(jnp.asarray(x * 4.0), 8)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1) * 4.0, rtol=1e-5)


def test_ste_gradient_is_identity():
    x = jax.random.normal(jax.random.key(0), (8, 16))
    g = jax.grad(lambda a: jnp.sum(fake_quant(a, 8) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(x))


def test_wire_bytes_accounting():
    # codes (int8) + f32 scale per row
    assert wire_bytes((4, 16, 32), 8) == 4 * 16 * 32 + 4 * 16 * 4
    assert wire_bytes((2, 8), 4) == 2 * 8 // 2 + 2 * 4


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 7).flatmap(
    lambda rows: st.integers(1, 16).flatmap(
        lambda half: st.lists(st.integers(-8, 7),
                              min_size=rows * half * 2,
                              max_size=rows * half * 2)
        .map(lambda v: np.asarray(v, np.int8).reshape(rows, half * 2)))))
def test_pack_int4_roundtrip_exact(codes):
    packed = pack_int4(jnp.asarray(codes))
    assert packed.dtype == jnp.int8
    assert packed.shape == (codes.shape[0], codes.shape[1] // 2)
    back = unpack_int4(packed)
    np.testing.assert_array_equal(np.asarray(back), codes)


def test_int4_wire_halves_code_bytes():
    shape = (4, 16, 32)
    scales = 4 * 16 * 4                      # f32 scale per row either way
    assert wire_bytes(shape, 8) - scales == 2 * (wire_bytes(shape, 4) - scales)


def test_fake_quant_equals_quant_dequant():
    x = jax.random.normal(jax.random.key(1), (16, 32))
    a = fake_quant(x, 8)
    c, s = quantize(x, 8)
    b = dequantize(c, s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
