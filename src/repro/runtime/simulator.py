"""The split-serving simulation: a topology of cells + one shared cloud.

A :class:`Topology` is a tuple of :class:`CellSpec`s.  Each cell owns its
own radio (:class:`~repro.runtime.wire.Wire` — link model + duplex), its
own fleet of one edge-device class (per-class
:class:`~repro.core.profiler.HardwareProfile`, per-cell ``edge_mp`` and
arrival rate), and — when adaptation is on — its own
:class:`~repro.runtime.controller.AdaptiveSplitController` routing that
cell's new arrivals to a per-cell ``(split, transport)`` pair.  Every cell
contends for ONE :class:`~repro.runtime.actors.CloudServer`: cross-cell
congestion (the fleet's combined slot occupancy plus background tenants) is
the shared signal the per-cell controllers react to, while uplink goodput
feedback stays per cell.  The classic single-uplink configuration
(``SimConfig(network=..., num_devices=...)``) is exactly a 1-cell topology
— the same code path, not a parallel one.

All timing is virtual (deterministic for a fixed seed); numerics are real
jax when ``numerics=True`` and skipped entirely in timing-only mode (used
by the fast benchmark sweeps and scheduler tests).

Serving modes:
  "split"  the paper: edge layers + butterfly reduce/quantize, compressed wire
  "cloud"  cloud-only offload: raw input features cross the wire
  "edge"   mobile-only: everything on the device, nothing crosses

Decode transports (split mode, multi-token requests — runtime/transports.py):
  "cache_handoff"  ship the edge stage-0 KV cache up; decode cloud-side
  "streamed"       edge keeps its cache; one (1, d_r) row up + one id down
                   per generated token
  "auto"           each cell's adaptive controller picks per request,
                   alongside the split (requires adapt=True)

Trace replay: any run's arrival stream (cell, device, t, prompt tokens) can
be recorded to JSONL (:meth:`Simulation.record_trace`) and rebuilt with
:func:`trace_arrivals`, making topology runs byte-for-byte reproducible and
letting real arrival logs drive the simulator.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.profiler import (GTX_1080TI, JETSON_TX2, HardwareProfile,
                                 get_device_class)
from repro.runtime.actors import (CloudServer, CloudSpec, EdgeDevice,
                                  SimRequest)
from repro.runtime.clock import EventLoop
from repro.runtime.faults import FaultInjector, FaultSchedule, RecoveryPolicy
from repro.runtime.gateway import Gateway, GatewayPolicy
from repro.runtime.metrics import JitProfiler, MetricsRegistry, MetricsSampler
from repro.runtime.split_exec import CostModel, SplitModelBank
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.tracing import NULL_TRACER, Tracer
from repro.runtime.wire import Wire


def ramp_load(t0: float, t1: float, l0: float = 0.0,
              l1: float = 0.95) -> Callable[[float], float]:
    """Background cloud load ramping linearly from l0@t0 to l1@t1."""
    def f(t: float) -> float:
        if t <= t0:
            return l0
        if t >= t1:
            return l1
        return l0 + (l1 - l0) * (t - t0) / (t1 - t0)
    return f


# ---------------------------------------------------------------------------
# topology: cells of heterogeneous fleets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CellSpec:
    """One cell of a topology: a radio + a fleet of one device class.

    ``device`` is a device-class name from
    :data:`repro.core.profiler.DEVICE_CLASSES` ("phone", "jetson", ...) or
    a :class:`HardwareProfile` directly.  ``None`` fields inherit the
    :class:`SimConfig` fleet-wide value.  ``wire`` names a wire group:
    cells sharing the same group name share ONE physical Wire (e.g. two
    fleets forced through a single congested uplink); by default each cell
    gets its own."""
    name: str
    network: str = "3g"
    num_devices: int = 4
    device: Union[str, HardwareProfile] = "jetson"
    duplex: Optional[str] = None             # None -> SimConfig.duplex
    edge_mp: int = 1
    arrival_rate: Optional[float] = None     # None -> SimConfig.arrival_rate
    num_requests: Optional[int] = None       # None -> even share of the total
    initial_split: Optional[int] = None      # None -> SimConfig.initial_split
    transport: Optional[str] = None          # None -> SimConfig.transport
    wire: Optional[str] = None               # wire-group key (shared uplink)

    def hardware(self) -> HardwareProfile:
        return get_device_class(self.device)


Topology = Tuple[CellSpec, ...]


def parse_topology(spec: str) -> Topology:
    """Inline topology grammar: comma-separated cells, each
    ``network[/duplex]:<N>x<class>[@rate]`` — e.g.
    ``"3g:4xphone,wifi:2xjetson"`` or ``"4g/shared:8xphone@30"``.  Cell
    names are ``<network><index>``."""
    cells: List[CellSpec] = []
    for i, part in enumerate(s.strip() for s in spec.split(",")):
        try:
            net, fleet = part.split(":")
            duplex = None
            if "/" in net:
                net, duplex = net.split("/")
            rate = None
            if "@" in fleet:
                fleet, rate_s = fleet.split("@")
                rate = float(rate_s)
            n, klass = fleet.split("x", 1)
            cells.append(CellSpec(
                name=f"{net}{i}", network=net, num_devices=int(n),
                device=klass, duplex=duplex, arrival_rate=rate))
        except ValueError:
            raise ValueError(
                f"bad cell spec {part!r}: expected "
                f"'network[/duplex]:<N>x<class>[@rate]' "
                f"(e.g. '3g:4xphone,wifi:2xjetson')") from None
        get_device_class(cells[-1].device)   # fail fast on unknown classes
    return tuple(cells)


class Cell:
    """Runtime state of one topology cell: its Wire, its cost model (edge
    device class x cloud), its device slice, and the (split, transport)
    pair its controller currently routes new arrivals to."""

    def __init__(self, spec: CellSpec, index: int, wire: Wire,
                 cost: CostModel, split: int, transport: str):
        self.spec = spec
        self.name = spec.name
        self.index = index
        self.wire = wire
        self.cost = cost
        self.dev_base = 0                    # set by the simulator
        self.current_split = split
        self.current_transport = transport
        self.controller: Optional[object] = None

    def set_split(self, split: int) -> None:
        self.current_split = split

    def set_transport(self, transport: str) -> None:
        self.current_transport = transport


# ---------------------------------------------------------------------------
# arrival traces: Poisson builder + JSONL record/replay
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Arrival:
    """One request of a pre-built arrival trace.  ``device`` is the global
    device index across the whole topology; ``cell`` the owning cell's
    index."""
    device: int
    t: float
    tokens: Optional[np.ndarray] = None      # prompt ids (numerics mode)
    cell: int = 0
    slo: str = "interactive"                 # SLO class (gateway.SLO_CLASSES)


def poisson_arrivals(*, num_devices: int, num_requests: int,
                     arrival_rate: float, prompt_len: int,
                     vocab_size: Optional[int] = None,
                     seed: int = 0, device_offset: int = 0,
                     cell: int = 0) -> List[Arrival]:
    """THE arrival-trace builder (shared by the simulator, the CLI and
    ``benchmarks.run runtime``): deterministic per-device Poisson
    inter-arrivals, plus prompt tokens when ``vocab_size`` is given.
    Building the trace once and passing it through ``SimConfig.arrivals``
    guarantees mode/wire/transport comparisons run the identical trace.
    ``device_offset`` shifts both the device ids and their rng streams, so
    each cell of a topology gets independent arrivals."""
    assert arrival_rate > 0, f"arrival_rate must be positive, got " \
        f"{arrival_rate} (quiesce a cell with num_requests=0 instead)"
    out: List[Arrival] = []
    per_dev = [num_requests // num_devices] * num_devices
    for i in range(num_requests % num_devices):
        per_dev[i] += 1
    for dev, n in enumerate(per_dev):
        rng = np.random.default_rng([seed, device_offset + dev])
        t = 0.0
        for _ in range(n):
            t += rng.exponential(1.0 / arrival_rate)
            tokens = None
            if vocab_size:
                tokens = rng.integers(0, vocab_size, size=(prompt_len,),
                                      dtype=np.int64).astype(np.int32)
            out.append(Arrival(device_offset + dev, t, tokens, cell))
    return out


# ---------------------------------------------------------------------------
# workload specs: the arrival-trace API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadSpec:
    """What traffic hits the fleet — THE arrival API (DESIGN.md section
    17).  ``SimConfig(workload=...)`` takes a spec or its string grammar
    and overrides the legacy ``num_requests``/``arrival_rate``/
    ``prompt_len`` fields, which keep working as a deprecation shim that
    maps onto ``WorkloadSpec(kind="poisson")`` — old-style configs build
    the identical arrival list.  Grammar: ``"<kind>:key=value,..."``, e.g.

      "poisson:rate=20,n=16"
      "pareto:alpha=1.5,rate=20,n=100000,interactive=0.25"
      "diurnal:rate=20,n=500,period=2.0,depth=0.8"
      "flash:rate=10,n=1000,at=0.2,dur=0.3,burst=20,alpha=1.5"

    ``interactive`` splits requests between the gateway's SLO classes; the
    class stream is drawn from its own namespaced rng, so turning it on
    never perturbs arrival times or prompt tokens."""
    kind: str = "poisson"            # poisson | pareto | diurnal | flash
    rate: Optional[float] = None     # per-device mean arrivals/s
    n: Optional[int] = None          # total requests across the topology
    prompt_len: Optional[int] = None
    interactive: float = 1.0         # fraction assigned the interactive class
    alpha: Optional[float] = None    # Pareto tail index (->1 = heavier);
    #                                  None = exponential gaps (pareto: 1.5)
    period_s: float = 1.0            # diurnal cycle length
    depth: float = 0.8               # diurnal trough is rate*(1-depth)
    at: float = 0.2                  # flash-crowd onset (s)
    dur: float = 0.2                 # flash-crowd duration (s)
    burst: float = 10.0              # flash-crowd rate multiplier

    KINDS = ("poisson", "pareto", "diurnal", "flash")

    def __post_init__(self):
        assert self.kind in self.KINDS, \
            f"unknown workload kind {self.kind!r} (one of {self.KINDS})"
        assert 0.0 <= self.interactive <= 1.0, self.interactive
        assert self.alpha is None or self.alpha > 1.0, \
            "Pareto gaps need alpha > 1 for a finite mean inter-arrival"
        assert 0.0 <= self.depth < 1.0, self.depth
        assert self.burst >= 1.0, self.burst

    @classmethod
    def parse(cls, spec: str) -> "WorkloadSpec":
        kind, _, rest = spec.partition(":")
        floats = {"rate": "rate", "interactive": "interactive",
                  "alpha": "alpha", "period": "period_s", "depth": "depth",
                  "at": "at", "dur": "dur", "burst": "burst"}
        ints = {"n": "n", "prompt_len": "prompt_len"}
        kw = {}
        for part in (p.strip() for p in rest.split(",") if p.strip()):
            key, eq, val = part.partition("=")
            if eq and key in floats:
                kw[floats[key]] = float(val)
            elif eq and key in ints:
                kw[ints[key]] = int(val)
            else:
                raise ValueError(
                    f"bad workload token {part!r}: expected "
                    f"<kind>:key=value,... with keys "
                    f"{sorted(floats) + sorted(ints)}")
        return cls(kind=kind.strip(), **kw)


def _assign_classes(arrivals: List[Arrival], interactive: float,
                    seed: int, device_offset: int) -> List[Arrival]:
    """SLO classes from a namespaced rng stream SEPARATE from the
    inter-arrival/token draws, so a class split never changes the trace
    timing or prompts (the legacy byte-identity contract)."""
    if interactive >= 1.0:
        return arrivals
    rng = np.random.default_rng([0x57, seed, device_offset])
    return [replace(a, slo="interactive" if rng.random() < interactive
                    else "batch") for a in arrivals]


def _modulated_arrivals(rate_of: Callable[[float], float], *,
                        num_devices: int, num_requests: int,
                        prompt_len: int, vocab_size: Optional[int] = None,
                        seed: int = 0, device_offset: int = 0, cell: int = 0,
                        alpha: Optional[float] = None) -> List[Arrival]:
    """Shared non-homogeneous builder: per-device unit-mean gap draws
    rescaled by the instantaneous rate.  ``alpha`` swaps the base draw
    from exponential to Pareto(alpha) with the same unit mean — heavy
    tails under any rate envelope.  Same per-device rng namespacing as
    :func:`poisson_arrivals`."""
    out: List[Arrival] = []
    per_dev = [num_requests // num_devices] * num_devices
    for i in range(num_requests % num_devices):
        per_dev[i] += 1
    for dev, n in enumerate(per_dev):
        rng = np.random.default_rng([seed, device_offset + dev])
        t = 0.0
        for _ in range(n):
            unit = rng.pareto(alpha) * (alpha - 1.0) if alpha is not None \
                else rng.exponential(1.0)
            t += unit / max(rate_of(t), 1e-9)
            tokens = None
            if vocab_size:
                tokens = rng.integers(0, vocab_size, size=(prompt_len,),
                                      dtype=np.int64).astype(np.int32)
            out.append(Arrival(device_offset + dev, t, tokens, cell))
    return out


def pareto_arrivals(*, num_devices: int, num_requests: int,
                    arrival_rate: float, prompt_len: int,
                    alpha: float = 1.5, vocab_size: Optional[int] = None,
                    seed: int = 0, device_offset: int = 0,
                    cell: int = 0) -> List[Arrival]:
    """Heavy-tailed arrivals: Pareto(alpha) inter-arrival gaps scaled to
    the same 1/arrival_rate mean as the Poisson builder — bursts and long
    idle gaps, the traffic shape that actually stresses admission
    control."""
    assert arrival_rate > 0 and alpha > 1.0, (arrival_rate, alpha)
    return _modulated_arrivals(
        lambda t: arrival_rate, num_devices=num_devices,
        num_requests=num_requests, prompt_len=prompt_len,
        vocab_size=vocab_size, seed=seed, device_offset=device_offset,
        cell=cell, alpha=alpha)


def diurnal_arrivals(*, num_devices: int, num_requests: int,
                     arrival_rate: float, prompt_len: int,
                     period_s: float = 1.0, depth: float = 0.8,
                     alpha: Optional[float] = None,
                     vocab_size: Optional[int] = None, seed: int = 0,
                     device_offset: int = 0, cell: int = 0) -> List[Arrival]:
    """Diurnal load curve: the rate swings cosine-shaped between the peak
    ``arrival_rate`` (t=0) and the trough ``arrival_rate*(1-depth)`` every
    ``period_s`` virtual seconds."""
    assert arrival_rate > 0 and period_s > 0, (arrival_rate, period_s)

    def rate_of(t: float) -> float:
        return arrival_rate * (
            1.0 - depth * 0.5 * (1.0 - float(np.cos(
                2.0 * np.pi * t / period_s))))
    return _modulated_arrivals(
        rate_of, num_devices=num_devices, num_requests=num_requests,
        prompt_len=prompt_len, vocab_size=vocab_size, seed=seed,
        device_offset=device_offset, cell=cell, alpha=alpha)


def flash_arrivals(*, num_devices: int, num_requests: int,
                   arrival_rate: float, prompt_len: int, at: float = 0.2,
                   dur: float = 0.2, burst: float = 10.0,
                   alpha: Optional[float] = None,
                   vocab_size: Optional[int] = None, seed: int = 0,
                   device_offset: int = 0, cell: int = 0) -> List[Arrival]:
    """Flash crowd: baseline ``arrival_rate`` except a ``burst``-times
    spike over ``[at, at+dur)`` — the shed-or-melt scenario the gateway
    benchmark runs (optionally with Pareto gaps via ``alpha``)."""
    assert arrival_rate > 0 and dur > 0, (arrival_rate, dur)

    def rate_of(t: float) -> float:
        return arrival_rate * burst if at <= t < at + dur else arrival_rate
    return _modulated_arrivals(
        rate_of, num_devices=num_devices, num_requests=num_requests,
        prompt_len=prompt_len, vocab_size=vocab_size, seed=seed,
        device_offset=device_offset, cell=cell, alpha=alpha)


def build_arrivals(spec: WorkloadSpec, *, num_devices: int, prompt_len: int,
                   vocab_size: Optional[int] = None, seed: int = 0,
                   device_offset: int = 0, cell: int = 0) -> List[Arrival]:
    """One cell's arrival trace from a :class:`WorkloadSpec`.  The
    ``poisson`` kind routes through :func:`poisson_arrivals` unchanged, so
    the legacy shim is byte-identical; every kind then gets its SLO
    classes from the separate class stream."""
    assert spec.rate is not None and spec.n is not None, \
        f"workload needs rate and n resolved, got {spec}"
    common = dict(num_devices=num_devices, num_requests=spec.n,
                  arrival_rate=spec.rate, prompt_len=prompt_len,
                  vocab_size=vocab_size, seed=seed,
                  device_offset=device_offset, cell=cell)
    if spec.kind == "poisson":
        out = poisson_arrivals(**common)
    elif spec.kind == "pareto":
        out = pareto_arrivals(alpha=spec.alpha or 1.5, **common)
    elif spec.kind == "diurnal":
        out = diurnal_arrivals(period_s=spec.period_s, depth=spec.depth,
                               alpha=spec.alpha, **common)
    else:
        out = flash_arrivals(at=spec.at, dur=spec.dur, burst=spec.burst,
                             alpha=spec.alpha, **common)
    return _assign_classes(out, spec.interactive, seed, device_offset)


# v2 adds the optional "faults" key to the header (the run's FaultSchedule,
# so a recorded chaotic run replays its fault sequence byte-for-byte); v3
# the per-arrival "slo" class key (the gateway's SLO classes survive record
# -> replay).  v1/v2 traces stay readable — their arrivals default to
# interactive and carry no schedule.
TRACE_FORMAT = "arrival-trace-v3"
LEGACY_TRACE_FORMATS = ("arrival-trace-v1", "arrival-trace-v2")


def record_arrivals(arrivals: Sequence[Arrival], path: str,
                    faults=None) -> None:
    """Write an arrival stream to JSONL (one line per arrival, preceded by
    a format header).  Floats round-trip exactly (json uses shortest-repr),
    so record -> replay -> record is byte-identical.  ``faults`` (a
    :class:`~repro.runtime.faults.FaultSchedule`) rides in the header —
    recorded even when empty, so the replay re-enables the fault layer."""
    header = {"format": TRACE_FORMAT, "n": len(arrivals)}
    if faults is not None:
        header["faults"] = faults.to_obj()
    with open(path, "w") as f:
        f.write(json.dumps(header) + "\n")
        for a in arrivals:
            tokens = None if a.tokens is None else \
                [int(x) for x in np.asarray(a.tokens)]
            f.write(json.dumps({"cell": a.cell, "device": a.device,
                                "slo": a.slo, "t": a.t, "tokens": tokens},
                               sort_keys=True) + "\n")


def trace_faults(path: str) -> Optional[FaultSchedule]:
    """The fault schedule recorded in a v2 trace header, or None for a
    fault-free (or v1) trace."""
    with open(path) as f:
        header = json.loads(f.readline())
    if "faults" not in header:
        return None
    return FaultSchedule.from_obj(header["faults"])


def trace_arrivals(path: str) -> List[Arrival]:
    """Rebuild the identical Arrival list from a recorded JSONL trace."""
    with open(path) as f:
        header = json.loads(f.readline())
        assert header.get("format") in (TRACE_FORMAT,) + \
            LEGACY_TRACE_FORMATS, \
            f"{path}: not an arrival trace (header {header!r})"
        out: List[Arrival] = []
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            tokens = rec.get("tokens")
            if tokens is not None:
                tokens = np.asarray(tokens, np.int32)
            out.append(Arrival(device=rec["device"], t=rec["t"],
                               tokens=tokens, cell=rec.get("cell", 0),
                               slo=rec.get("slo", "interactive")))
    assert len(out) == header["n"], \
        f"{path}: truncated trace ({len(out)} of {header['n']} arrivals)"
    return out


# ---------------------------------------------------------------------------
# simulation config + driver
# ---------------------------------------------------------------------------


@dataclass
class SimConfig:
    cfg: object                              # ModelConfig (butterfly optional)
    mode: str = "split"                      # split | cloud | edge
    wire_mode: str = "int8"                  # raw | reduced | int8 | int4 | entropy
    transport: str = "cache_handoff"         # cache_handoff | streamed | progressive | auto
    network: str = "3g"                      # 3g | 4g | wifi | inter_pod
    duplex: str = "split"                    # split | shared downlink FIFO
    num_devices: int = 4
    num_requests: int = 16                   # total across all cells
    arrival_rate: float = 20.0               # per device, requests/s
    prompt_len: int = 32
    max_new_tokens: int = 4
    d_r: int = 16
    initial_split: int = 1
    candidate_splits: Optional[Sequence[int]] = None
    edge: HardwareProfile = JETSON_TX2
    cloud: HardwareProfile = GTX_1080TI
    # a multi-cell topology overrides the single-uplink fields above
    # (network/duplex/num_devices/edge/edge_mp); the 1-cell default IS the
    # classic configuration, built through the same path
    topology: Optional[Sequence[CellSpec]] = None
    # model-axis degree of each half's stage (DESIGN.md section 11): timing
    # divides by the degree, and in numerics mode the bank's jitted halves
    # really run shard_map'd over that many local devices (heterogeneous
    # edge=1 / cloud=N is the expected shape)
    edge_mp: int = 1
    cloud_mp: int = 1
    background_load: Optional[Callable[[float], float]] = None
    adapt: bool = False
    control_interval_s: float = 0.05
    objective: str = "latency"               # a planner.SELECTION_OBJECTIVES key
    slo_ms: Optional[float] = None           # SLO for energy_under_slo
    max_concurrent: int = 8
    seed: int = 0
    numerics: bool = True
    arrivals: Optional[Sequence[Arrival]] = None   # overrides Poisson build
    # workload spec (a WorkloadSpec or its grammar string): THE arrival
    # API.  Its rate/n/prompt_len override the three legacy fields above,
    # which remain a deprecation shim onto WorkloadSpec(kind="poisson").
    workload: Optional[Union[str, WorkloadSpec]] = None
    # flight recorder (all opt-in; the default path is byte-identical to a
    # build without any of it)
    trace: bool = False                      # virtual-clock span tracing
    metrics: bool = False                    # fixed-interval metrics sampler
    metrics_interval_s: float = 0.01
    profile_jit: bool = False                # wall-clock jit attribution
    # fault injection (runtime/faults.py): a FaultSchedule, a DSL string
    # ("leave@0.05:2,outage@0.3+0.1"), or None.  Setting either field
    # builds the FaultInjector (watchdog + retry state machine included);
    # with both None the fault layer is entirely absent and the run is
    # byte-identical to a build without the module.
    faults: Optional[object] = None
    recovery: Optional[RecoveryPolicy] = None
    # serving gateway (runtime/gateway.py): a GatewayPolicy, its grammar
    # string, or None.  The all-off GatewayPolicy() is byte-identical to
    # None (asserted in tests) — the same contract the fault layer makes.
    gateway: Optional[Union[str, GatewayPolicy]] = None


class Simulation:
    def __init__(self, sim_cfg: SimConfig):
        c = sim_cfg
        # resolve the workload spec first: its rate/n/prompt_len override
        # the legacy SimConfig fields everywhere downstream (max_len,
        # controllers, arrival builders all read the resolved values)
        self.workload: Optional[WorkloadSpec] = None
        if c.workload is not None:
            w = WorkloadSpec.parse(c.workload) \
                if isinstance(c.workload, str) else c.workload
            self.workload = w
            overrides = {k: v for k, v in (("arrival_rate", w.rate),
                                           ("num_requests", w.n),
                                           ("prompt_len", w.prompt_len))
                         if v is not None}
            if overrides:
                c = replace(c, **overrides)
        assert c.mode in ("split", "cloud", "edge"), c.mode
        assert c.transport in ("cache_handoff", "streamed", "progressive",
                               "auto"), c.transport
        if c.transport == "auto":
            assert c.adapt and c.mode == "split", \
                "transport='auto' needs the adaptive controller (split mode)"
        base = c.cfg
        if base.butterfly is not None:
            base = replace(base, butterfly=None)
        self.sim_cfg = c
        self.base_cfg = base
        self.loop = EventLoop()
        self.tracer = Tracer() if c.trace else NULL_TRACER
        self.registry = MetricsRegistry()
        self.telemetry = Telemetry(self.registry)
        self.candidates = list(c.candidate_splits) if c.candidate_splits \
            else list(range(1, base.num_layers))

        # every configuration is a topology; the classic single-uplink
        # SimConfig fields synthesize the 1-cell special case
        specs = tuple(c.topology) if c.topology else (CellSpec(
            name="cell0", network=c.network, num_devices=c.num_devices,
            device=c.edge, duplex=c.duplex, edge_mp=c.edge_mp),)
        names = [s.name for s in specs]
        assert len(set(names)) == len(names), f"duplicate cell names {names}"
        self.cells: List[Cell] = []
        wires = {}
        edge_mps = set()
        for i, spec in enumerate(specs):
            key = spec.wire or spec.name
            if key not in wires:
                wires[key] = Wire.named(spec.network,
                                        duplex=spec.duplex or c.duplex)
                wires[key].tracer = self.tracer
                # group key, not network name: two cells on the same network
                # still get distinct trace tracks
                wires[key].track_prefix = f"wire/{key}"
            else:
                assert wires[key].name == spec.network, \
                    f"wire group {key!r} spans networks " \
                    f"{wires[key].name!r} and {spec.network!r}"
            split = spec.initial_split if spec.initial_split is not None \
                else c.initial_split
            assert split in self.candidates, \
                f"cell {spec.name}: initial split {split} not in " \
                f"{self.candidates}"
            tp_mode = spec.transport or c.transport
            assert tp_mode in ("cache_handoff", "streamed", "progressive",
                               "auto"), tp_mode
            cost = CostModel(base, spec.hardware(), c.cloud,
                             edge_mp=spec.edge_mp, cloud_mp=c.cloud_mp)
            self.cells.append(Cell(
                spec, i, wires[key], cost, split,
                "cache_handoff" if tp_mode == "auto" else tp_mode))
            edge_mps.add(spec.edge_mp)

        self.wires = wires
        self.profiler = JitProfiler() if (c.profile_jit and c.numerics) \
            else None
        self.bank = SplitModelBank(base, c.d_r, wire_mode=c.wire_mode,
                                   seed=c.seed, edge_mp=min(edge_mps),
                                   cloud_mp=c.cloud_mp,
                                   profiler=self.profiler) \
            if c.numerics else None
        # cloud-side cost model (the server only charges cloud durations;
        # cell 0's is exact for the 1-cell configuration)
        self.cost = self.cells[0].cost
        self._remaining = 0
        self.server = CloudServer(
            CloudSpec(cost=self.cost, bank=self.bank, mode=c.mode,
                      d_r=c.d_r, max_concurrent=c.max_concurrent,
                      background_load=c.background_load, engine_seed=c.seed,
                      max_len=c.prompt_len + c.max_new_tokens + 2,
                      numerics_split=self.cells[0].current_split),
            loop=self.loop, telemetry=self.telemetry,
            wire=self.cells[0].wire, on_done=self._on_done)
        self.server.tracer = self.tracer
        self.devices: List[EdgeDevice] = []
        for cell in self.cells:
            cell.dev_base = len(self.devices)
            for i in range(cell.spec.num_devices):
                self.devices.append(EdgeDevice(
                    len(self.devices), loop=self.loop, cost=cell.cost,
                    uplink=cell.wire, server=self.server, bank=self.bank,
                    mode=c.mode, wire_mode=c.wire_mode, d_r=c.d_r,
                    telemetry=self.telemetry,
                    numerics_split=cell.current_split,
                    cell=cell.name, cell_index=cell.index))
                self.devices[-1].tracer = self.tracer
        self.server.devices = self.devices       # downlink delivery targets
        self.gateway: Optional[Gateway] = None
        if c.gateway is not None:
            policy = GatewayPolicy.parse(c.gateway) \
                if isinstance(c.gateway, str) else c.gateway
            if policy.autoscale:
                assert not c.numerics, \
                    "autoscaled replicas are a timing-only capacity model " \
                    "(the serving engines are built at a fixed max_batch)"
            self.gateway = Gateway(policy, loop=self.loop,
                                   server=self.server,
                                   telemetry=self.telemetry)
        self.controllers: List[object] = []
        if c.adapt and c.mode == "split":
            from repro.runtime.controller import AdaptiveSplitController
            for cell in self.cells:
                spec = cell.spec
                tp_mode = spec.transport or c.transport
                # a cell whose breaker is open sees a ceilinged cloud load
                # (the gateway is refusing its traffic), so its controller
                # routes edge-heavy exactly as during a cloud outage
                cloud_load = self.gateway.cell_load_fn(cell.name) \
                    if self.gateway is not None else self.server.current_load
                cell.controller = AdaptiveSplitController(
                    loop=self.loop, uplink=cell.wire,
                    cloud_load=cloud_load,
                    cfg=base, d_r=c.d_r, seq=c.prompt_len,
                    candidate_splits=self.candidates,
                    edge=spec.hardware(), cloud=c.cloud,
                    wire_mode=c.wire_mode,
                    telemetry=self.telemetry,
                    set_split=cell.set_split,
                    get_split=lambda cell=cell: cell.current_split,
                    interval_s=c.control_interval_s,
                    handoff_bytes_per_layer=(
                        cell.cost.stage0_cache_bytes(c.prompt_len, 1)
                        if c.max_new_tokens > 1 else 0.0),
                    objective=c.objective,
                    slo_s=c.slo_ms / 1e3 if c.slo_ms else None,
                    transport_mode=tp_mode,
                    new_tokens=c.max_new_tokens,
                    set_transport=cell.set_transport,
                    get_transport=lambda cell=cell: cell.current_transport,
                    edge_mp=spec.edge_mp, cloud_mp=c.cloud_mp,
                    cell=cell.name, tracer=self.tracer)
                self.controllers.append(cell.controller)
                if self.gateway is not None:
                    # breaker open/close transitions nudge the cell's
                    # controller off-cycle, like a link handover does
                    self.gateway.pokes[cell.name] = cell.controller.poke
        self.injector: Optional[FaultInjector] = None
        self.fault_schedule: Optional[FaultSchedule] = None
        if c.faults is not None or c.recovery is not None:
            sched = c.faults
            if isinstance(sched, str):
                sched = FaultSchedule.parse(sched)
            elif sched is None:
                sched = FaultSchedule()
            self.fault_schedule = sched
            self.injector = FaultInjector(self, sched, c.recovery)
            self.server.injector = self.injector
            for d in self.devices:
                d.injector = self.injector
        self._register_tracks()
        self._in_flight = {cell.name: 0 for cell in self.cells}
        self.sampler = self._build_sampler() if c.metrics else None
        self.arrivals: List[Arrival] = (
            list(c.arrivals) if c.arrivals is not None
            else self._build_arrivals())
        self._validate_arrivals()
        self._remaining = len(self.arrivals)

    # ------------------------------------------------------------------ api
    @property
    def uplink(self) -> Wire:
        """Cell 0's Wire (THE uplink of a single-cell configuration)."""
        return self.cells[0].wire

    @property
    def current_split(self) -> int:
        return self.cells[0].current_split

    @property
    def current_transport(self) -> str:
        return self.cells[0].current_transport

    @property
    def controller(self) -> Optional[object]:
        return self.controllers[0] if self.controllers else None

    def cell_of(self, device: int) -> Cell:
        return self.cells[self.devices[device].cell_index]

    def record_trace(self, path: str) -> None:
        """Record this run's arrival stream (cell, device, t, prompt) to
        JSONL; :func:`trace_arrivals` rebuilds the identical list, so the
        replayed simulation is byte-for-byte identical.  A configured fault
        schedule rides in the header (:func:`trace_faults` recovers it)."""
        record_arrivals(self.arrivals, path, faults=self.fault_schedule)

    def run(self) -> Telemetry:
        self._schedule_arrivals()
        if self.injector is not None:
            self.injector.start()
        for ctl in self.controllers:
            ctl.start()
        if self.sampler is not None:
            self.sampler.start()
        if self.gateway is not None:
            self.gateway.start()
        self.loop.run()
        if self._remaining:
            # without the fault layer every request must complete; with it,
            # anything the watchdog missed is failed as lost — the loop
            # draining early must never leave a request unaccounted
            assert self.injector is not None, \
                f"{self._remaining} requests never completed"
            for req in self.requests:
                if not req.finished:
                    self.injector.fail(req, "lost")
        if self.bank is not None:
            c = self.telemetry.counters
            c["engine_decode_steps"] = sum(
                e.decode_steps for e in self.server._engines.values()) + sum(
                d._local_engine.decode_steps for d in self.devices
                if d._local_engine is not None)
            c["bank_jit_cache_entries"] = self.bank.jit_cache_entries
            c["bank_jit_cache_hits"] = self.bank.cache_hits
            c["bank_jit_cache_misses"] = self.bank.cache_misses
        if self.profiler is not None:
            self.telemetry.jit_profile = {
                "headline": self.profiler.headline(),
                "entries": self.profiler.summary()}
        return self.telemetry

    # ------------------------------------------------------------- internals
    def _register_tracks(self) -> None:
        """Pre-register every trace track in topology order so the exported
        file lists them deterministically (and readably) even for tracks
        that end up empty."""
        if not self.tracer.enabled:
            return
        for d in self.devices:
            self.tracer.track(d.track)
        for key, w in self.wires.items():
            self.tracer.track(f"{w.track_prefix}/up")
            self.tracer.track(f"{w.track_prefix}/down")
        self.tracer.track("cloud/accel")
        for i in range(self.sim_cfg.max_concurrent):
            self.tracer.track(f"cloud/slot{i}")
        for cell in self.cells:
            if cell.controller is not None:
                self.tracer.track(f"ctl/{cell.name}")
            self.tracer.track(f"req/{cell.name}")
        if self.injector is not None:
            self.tracer.track("faults/sched")

    def _build_sampler(self) -> MetricsSampler:
        """Wire the fixed-interval sampler to read-only views of runtime
        state: queue depths, per-direction wire occupancy + windowed
        goodput, cloud batch size/occupancy, per-cell in-flight counts."""
        sampler = MetricsSampler(self.loop, self.registry,
                                 interval_s=self.sim_cfg.metrics_interval_s)
        srv = self.server
        sampler.add_source("cloud/load", srv.current_load)
        sampler.add_source("cloud/active",
                           lambda now: float(srv.num_active))
        sampler.add_source("cloud/decoding",
                           lambda now: float(srv.num_decoding))
        sampler.add_source("cloud/pending",
                           lambda now: float(len(srv.pending)))
        sampler.add_source("cloud/available",
                           lambda now: 0.0 if now < srv.outage_until else 1.0)
        for key, w in self.wires.items():
            sampler.add_source(f"wire/{key}/up_backlog_s", w.up_backlog_s)
            sampler.add_source(f"wire/{key}/down_backlog_s",
                               w.down_backlog_s)
            sampler.add_source(f"wire/{key}/up_goodput_bps",
                               w.observed_bytes_per_s)
            sampler.add_source(f"wire/{key}/down_goodput_bps",
                               w.observed_down_bytes_per_s)
        for cell in self.cells:
            # membership resolves at sample time: devices that JOIN the cell
            # mid-run (fault layer churn) enter the gauge
            sampler.add_source(
                f"cell/{cell.name}/queue_depth",
                lambda now, ci=cell.index: float(sum(
                    d.queue_depth(now) for d in self.devices
                    if d.cell_index == ci)))
            sampler.add_source(
                f"cell/{cell.name}/in_flight",
                lambda now, name=cell.name: float(self._in_flight[name]))
        return sampler

    def _build_arrivals(self) -> List[Arrival]:
        """Per-cell arrival streams through the :class:`WorkloadSpec` path
        (the legacy rate/n fields synthesize the Poisson spec): explicit
        CellSpec.num_requests is honored, the rest of the fleet-wide total
        splits evenly (remainder to earlier cells) — the 1-cell Poisson
        case reduces to the classic builder byte-for-byte."""
        c = self.sim_cfg
        base_spec = self.workload or WorkloadSpec()
        explicit = sum(s.spec.num_requests or 0 for s in self.cells)
        open_cells = [cell for cell in self.cells
                      if cell.spec.num_requests is None]
        left = max(c.num_requests - explicit, 0)
        share = [left // len(open_cells)] * len(open_cells) if open_cells \
            else []
        for i in range(left % len(open_cells) if open_cells else 0):
            share[i] += 1
        shares = iter(share)
        out: List[Arrival] = []
        for cell in self.cells:
            spec = cell.spec
            n = spec.num_requests if spec.num_requests is not None \
                else next(shares)
            out.extend(build_arrivals(
                replace(base_spec, n=n,
                        rate=spec.arrival_rate
                        if spec.arrival_rate is not None else c.arrival_rate),
                num_devices=spec.num_devices, prompt_len=c.prompt_len,
                vocab_size=self.base_cfg.vocab_size if c.numerics else None,
                seed=c.seed, device_offset=cell.dev_base, cell=cell.index))
        return out

    def _validate_arrivals(self) -> None:
        for a in self.arrivals:
            assert 0 <= a.device < len(self.devices), \
                f"arrival device {a.device} outside the fleet " \
                f"({len(self.devices)} devices)"
            assert self.devices[a.device].cell_index == a.cell, \
                f"arrival routes device {a.device} to cell {a.cell} but it " \
                f"lives in cell {self.devices[a.device].cell_index} — " \
                f"replayed trace does not match this topology"

    def _on_done(self, req: SimRequest) -> None:
        self._remaining -= 1
        t = req.trace
        self._in_flight[t.cell] -= 1
        if self.tracer.enabled:
            self.tracer.async_span(
                f"req/{t.cell}", "request", t.uid, t.t_arrival, t.t_done,
                args={"uid": t.uid, "device": t.device, "split": t.split,
                      "transport": t.transport})
        if self._remaining == 0:
            for ctl in self.controllers:
                ctl.stop()
            if self.sampler is not None:
                self.sampler.stop()
            if self.injector is not None:
                self.injector.stop()    # cancel the watchdog: loop can drain
            if self.gateway is not None:
                self.gateway.stop()     # cancel the autoscale tick

    def _schedule_arrivals(self) -> None:
        c = self.sim_cfg
        self.requests: List[SimRequest] = []
        for uid, a in enumerate(self.arrivals):
            assert not c.numerics or a.tokens is not None, \
                "numerics mode needs prompt tokens in the arrival trace"
            trace = RequestTrace(
                uid=uid, device=a.device, mode=c.mode, wire_mode=c.wire_mode,
                split=0, prompt_len=c.prompt_len,
                cell=self.cells[a.cell].name, slo_class=a.slo)
            req = SimRequest(trace=trace, tokens=a.tokens,
                             max_new_tokens=c.max_new_tokens)
            self.requests.append(req)
            self.loop.schedule_at(a.t, self._make_arrival(a.device, req))

    def _make_arrival(self, dev: int, req: SimRequest) -> Callable[[], None]:
        def fire() -> None:
            # split and transport are pinned when the mobile starts the
            # request — the owning cell's latest controller decision governs
            # new arrivals only
            cell = self.cell_of(dev)
            self._in_flight[cell.name] += 1
            if self.sim_cfg.mode == "split":
                req.trace.split = cell.current_split
                req.trace.transport = cell.current_transport
            elif self.sim_cfg.mode == "edge":
                req.trace.split = self.base_cfg.num_layers
            else:
                req.trace.split = 0
            target = dev if self.injector is None else \
                self.injector.route(dev)
            if target < 0:                  # cell fully evicted: dead letter
                req.trace.t_arrival = self.loop.now
                self.injector.fail(req, "no_device_in_cell")
                return
            self.devices[target].on_arrival(req)
        return fire


def run_sim(sim_cfg: SimConfig) -> Telemetry:
    return Simulation(sim_cfg).run()
