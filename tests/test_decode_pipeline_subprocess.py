"""Pipelined decode must be a pure schedule change: greedy tokens from the
two-microbatch rotation (edge decodes mb k+1 while cloud decodes mb k) are
bitwise-identical to serial decode, for the int8 and packed-int4 wires and
for the fused kernel path.  Needs a (pod=2, model=4) mesh -> 8 host devices,
so it runs in a subprocess with its own XLA_FLAGS."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess

DENSE_CODE = r"""
import os, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import model as M
from repro.serving.pipeline import make_decode_pipeline

cfg = get_config("qwen3-8b").reduced()
cfg = dataclasses.replace(cfg, num_kv_heads=4).with_butterfly(layer=1, d_r=32)
built = M.build(cfg)
params, _ = M.init_model(jax.random.key(0), built)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4, 1), ("pod", "model", "data"))
Mmb, mb, S, T = 2, 2, 8, 4
toks = jax.random.randint(jax.random.key(1), (Mmb * mb, S), 0, cfg.vocab_size)

def run(**kw):
    return jax.jit(make_decode_pipeline(
        built, mesh, Mmb, S, mb, T, **kw))(params, toks)

ref = run(wire_mode="int8", pipelined=False)
assert ref.shape == (Mmb * mb, T)
assert (run(wire_mode="int8", pipelined=True) == ref).all(), "int8 parity"

# int4: pipelined == serial bitwise (both use the same packed wire)
s4 = run(wire_mode="int4", pipelined=False)
assert (run(wire_mode="int4", pipelined=True) == s4).all(), "int4 parity"

# fused reduce+quant / restore+norm1 kernels + psum overlap, against the
# plain serial eager path: same wire numerics, so same greedy tokens
fused = run(wire_mode="int8", pipelined=True, use_kernel=True,
            overlap_psum=True)
assert (fused == ref).all(), "fused kernel parity"

pipe = jax.jit(make_decode_pipeline(built, mesh, Mmb, S, mb, T,
                                    wire_mode="int4", pipelined=True))
hlo = pipe.lower(params, toks).compile().as_text()
assert any("collective-permute" in l and "s8[" in l
           for l in hlo.splitlines()), "wire must cross pods as int8 codes"
print("DECODE_PIPE_DENSE_OK")
"""

MOE_CODE = r"""
import os, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import model as M
from repro.serving.pipeline import make_decode_pipeline

cfg = get_config("qwen3-moe-235b-a22b").reduced()
cfg = dataclasses.replace(cfg, num_kv_heads=4).with_butterfly(layer=1, d_r=32)
built = M.build(cfg)
params, _ = M.init_model(jax.random.key(0), built)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4, 1), ("pod", "model", "data"))
Mmb, mb, S, T = 2, 2, 8, 4
toks = jax.random.randint(jax.random.key(1), (Mmb * mb, S), 0, cfg.vocab_size)

def run(**kw):
    return jax.jit(make_decode_pipeline(
        built, mesh, Mmb, S, mb, T, **kw))(params, toks)

ref = run(wire_mode="int8", pipelined=False)
assert (run(wire_mode="int8", pipelined=True) == ref).all(), "moe int8 parity"
assert (run(wire_mode="int4", pipelined=True) ==
        run(wire_mode="int4", pipelined=False)).all(), "moe int4 parity"
print("DECODE_PIPE_MOE_OK")
"""


def _run(code, marker):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stderr[-3000:]
    assert marker in res.stdout


def test_decode_pipeline_parity_dense():
    _run(DENSE_CODE, "DECODE_PIPE_DENSE_OK")


def test_decode_pipeline_parity_moe():
    _run(MOE_CODE, "DECODE_PIPE_MOE_OK")
