"""Split-serving runtime: an event-driven edge/cloud request simulator.

The paper's headline numbers come from *deploying* the butterfly split under
request traffic and adapting the partition point to server load (Sec. III-C).
This package provides the missing request-stream layer on top of the repo's
static pieces:

  clock.py       deterministic discrete-event loop (reproducible traces)
  wire.py        contended uplink + downlink over core/wireless link models
  telemetry.py   per-request latency/energy breakdown + p50/p95/p99
  split_exec.py  real jax numerics for the edge/cloud halves + cost model
  transports.py  pluggable decode transports (cache handoff vs streamed rows)
  actors.py      edge-device fleet and the cloud continuous-batching server
  controller.py  adaptive split + transport control (online selection phase)
  simulator.py   ties the above into a runnable simulation

Entry points: ``repro.launch.runtime_sim`` (CLI) and
``benchmarks.run runtime`` (JSON comparison vs cloud-only offload).
"""
from repro.runtime.clock import EventLoop
from repro.runtime.controller import AdaptiveSplitController
from repro.runtime.simulator import SimConfig, Simulation, poisson_arrivals
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.transports import DecodeTransport, get_transport
from repro.runtime.wire import Uplink, Wire

__all__ = ["EventLoop", "AdaptiveSplitController", "SimConfig", "Simulation",
           "RequestTrace", "Telemetry", "Uplink", "Wire", "DecodeTransport",
           "get_transport", "poisson_arrivals"]
