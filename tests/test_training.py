"""Training substrate: optimizer semantics, learning on synthetic data,
checkpoint round-trips, butterfly-vs-vanilla accuracy gap at small scale."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import model as M
from repro.training import (AdamWConfig, adamw_init, adamw_update,
                            constant_schedule, cosine_schedule,
                            make_train_step)
from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=constant_schedule(0.1), weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=constant_schedule(1.0), grad_clip=1e-3,
                      weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _, gnorm = adamw_update(cfg, params, g, opt)
    assert float(gnorm) > 1e5            # raw norm reported
    assert float(jnp.max(jnp.abs(p2["w"]))) <= 1.1  # update bounded by lr


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=0.01)
    assert float(s(100)) == pytest.approx(0.1, abs=0.02)


def test_tiny_lm_learns():
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=64)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(built, AdamWConfig(lr=constant_schedule(3e-3))))
    losses = []
    for i, raw in zip(range(50), lm_batches(cfg.vocab_size, 32, 8)):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_butterfly_gap_small_after_training():
    """Paper claim at micro scale: the butterfly model reaches ~the vanilla
    model's loss (here: within 15% after the same step budget)."""
    def run(with_bf):
        cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=64)
        if with_bf:
            cfg = cfg.with_butterfly(layer=1, d_r=32)
        built = M.build(cfg)
        params, _ = M.init_model(jax.random.key(0), built)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(built,
                                       AdamWConfig(lr=constant_schedule(3e-3))))
        last = None
        for i, raw in zip(range(60), lm_batches(cfg.vocab_size, 32, 8, seed=7)):
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, m = step(params, opt, batch)
            last = float(m["loss"])
        return last

    vanilla = run(False)
    butterfly = run(True)
    assert butterfly < vanilla * 1.15 + 0.2, (vanilla, butterfly)


def test_checkpoint_roundtrip_exact():
    cfg = get_config("xlstm-125m").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(f"{d}/ck", params, opt, step=3,
                               metadata={"arch": cfg.name})
        zeroed = jax.tree.map(jnp.zeros_like, params)
        p2, o2, meta = restore_checkpoint(path, zeroed, jax.tree.map(
            jnp.zeros_like, opt))
        assert meta["step"] == 3 and meta["arch"] == cfg.name
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remat_matches_plain():
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=64)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    batch_raw = next(iter(lm_batches(cfg.vocab_size, 16, 4)))
    batch = {k: jnp.asarray(v) for k, v in batch_raw.items()}
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=constant_schedule(1e-3))
    p1, _, m1 = jax.jit(make_train_step(built, ocfg, remat=False))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(built, ocfg, remat=True))(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 over batch 8 == one step over the same batch 8
    (identical grads up to f32 summation order)."""
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), vocab_size=64)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    batch_raw = next(iter(lm_batches(cfg.vocab_size, 16, 8)))
    batch = {k: jnp.asarray(v) for k, v in batch_raw.items()}
    ocfg = AdamWConfig(lr=constant_schedule(1e-3))
    p1, _, m1 = jax.jit(make_train_step(built, ocfg, accum_steps=1))(
        params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(built, ocfg, accum_steps=2))(
        params, adamw_init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)
