"""Paper Sec. III-C: the partition point adapts to server load at runtime.

The mobile pings the server, gets its load level K_cloud, and re-runs
Algorithm 1's selection phase over the M hosted partitioned models —
congestion pushes the split deeper (more work stays on the edge) while still
offloading less data than the raw input.

This driver sweeps cloud load 0% -> 97.5% for ResNet-50 (the paper's model,
with its published minimal D_r per split) and for a transformer (qwen3-8b on
the TPU edge/cloud profile), printing the selected split per (network, load),
then runs the *closed-loop* version: the split-serving runtime's adaptive
controller re-running the selection phase online against a live load ramp
(repro/runtime — the one-shot sweep made continuous).

Run:  PYTHONPATH=src python examples/load_adaptation.py
"""
from repro.configs import get_config
from repro.configs.resnet50 import PAPER_MIN_DR, resnet50
from repro.core import costs
from repro.core.planner import (TrainingPhaseResult, plan_transformer_split,
                                profiling_phase, selection_phase)
from repro.core.profiler import GTX_1080TI, JETSON_TX2, TPU_V5E
from repro.core.wireless import INTER_POD, NETWORKS

LOADS = [0.0, 0.5, 0.9, 0.975]


def resnet_sweep():
    cfg = resnet50()
    trained = [TrainingPhaseResult(s, PAPER_MIN_DR[s], 0.74)
               for s in range(1, 17)]

    def split_costs(split, d_r):
        ef, cf, wire = costs.resnet_split_flops(cfg, split, d_r)
        return ef, ef / 10, cf, cf / 10, wire

    print("ResNet-50 (paper's model), selected split vs cloud load:")
    print(f"  {'load':>6s} " + " ".join(f"{n:>6s}" for n in NETWORKS))
    for load in LOADS:
        profiles = profiling_phase(trained, split_costs, JETSON_TX2,
                                   GTX_1080TI, cloud_load=load)
        row = [selection_phase(profiles, net, "latency").split
               for net in NETWORKS.values()]
        print(f"  {load:6.1%} " + " ".join(f"RB{r:<4d}" for r in row))
    print("  (congestion pushes the split deeper, exactly Sec III-C)\n")


def transformer_sweep():
    cfg = get_config("qwen3-8b")
    print("qwen3-8b on the pod boundary (edge pod <-> cloud pod, d_r=256):")
    print(f"  {'load':>6s} {'split':>6s} {'latency':>10s} {'wire':>10s} "
          f"{'compression':>12s}")
    for load in LOADS:
        best, _ = plan_transformer_split(
            cfg, seq=2048, batch=8, edge=TPU_V5E, cloud=TPU_V5E,
            interconnect=INTER_POD, d_r=256,
            candidate_splits=list(range(1, cfg.num_layers)),
            cloud_load=load)
        print(f"  {load:6.1%} {best['split']:>6d} "
              f"{best['latency_s']*1e3:9.2f}ms {best['wire_bytes']/1e6:9.2f}MB "
              f"{best['compression']:11.1f}x")


def runtime_closed_loop():
    """Sec. III-C as a running system: Poisson traffic, a background load
    ramp, and the controller moving the split between arrivals."""
    import dataclasses

    from repro.configs import get_config
    from repro.core.profiler import JETSON_TX2
    from repro.runtime.simulator import SimConfig, Simulation, ramp_load

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), num_layers=4)
    sc = SimConfig(cfg=cfg, network="3g", num_devices=4, num_requests=64,
                   arrival_rate=40.0, prompt_len=32, max_new_tokens=1,
                   d_r=16, adapt=True, control_interval_s=0.02,
                   cloud=JETSON_TX2.scaled(10, "cloud_slice"),
                   background_load=ramp_load(0.0, 0.25, 0.0, 0.97),
                   numerics=False, metrics=True, metrics_interval_s=0.05)
    sim = Simulation(sc)
    tel = sim.run()
    print("\nclosed-loop runtime (4-layer qwen3, cloud = 10x edge, "
          "load ramp 0 -> 97%):")
    print(f"  {'t':>7s} {'load':>7s} {'split':>6s}")
    last = None
    for d in tel.decisions:
        if d.new_split != last:
            print(f"  {d.t:6.2f}s {d.cloud_load:7.1%} {d.new_split:>6d}")
            last = d.new_split
    # the same ramp seen through the metrics sampler (SimConfig(metrics=True)):
    # queue depth and uplink goodput around the moment the controller moves
    wire_key = next(iter(sim.wires))
    print(f"  metrics timeline ({len(sim.sampler.rows)} samples @ "
          f"{sc.metrics_interval_s*1e3:.0f}ms):")
    print(f"  {'t':>7s} {'load':>7s} {'queue':>6s} {'in_flight':>9s} "
          f"{'goodput':>12s}")
    cell = sim.cells[0].name
    for row in sim.sampler.rows:
        print(f"  {row['t']:6.2f}s {row['cloud/load']:7.1%} "
              f"{row[f'cell/{cell}/queue_depth']:6.0f} "
              f"{row[f'cell/{cell}/in_flight']:9.0f} "
              f"{row[f'wire/{wire_key}/up_goodput_bps']/1e3:9.1f} kB/s")
    s = tel.summary()
    print(f"  {s['n_requests']:.0f} requests, latency p50 "
          f"{s['latency_p50_ms']:.2f} ms, p99 {s['latency_p99_ms']:.2f} ms "
          "(the controller holds RB-shallow until congestion makes the "
          "derated cloud slower than the edge, then goes deep)")


def topology_closed_loop():
    """Multi-cell topologies (DESIGN.md section 12): heterogeneous fleets on
    per-cell radios, per-cell controllers, one congested cloud — the cells
    settle on different (split, transport) pairs."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.simulator import SimConfig, Simulation, parse_topology

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), num_layers=4)
    sc = SimConfig(cfg=cfg, topology=parse_topology("3g:4xjetson,wifi:4xphone"),
                   num_requests=48, prompt_len=64, max_new_tokens=8, d_r=16,
                   adapt=True, transport="auto", control_interval_s=0.02,
                   background_load=lambda t: 0.95, numerics=False)
    sim = Simulation(sc)
    tel = sim.run()
    print("\nmulti-cell topology (jetson gateways on 3g + phones on wifi, "
          "cloud at 95% load):")
    per_cell = tel.cell_summary()
    for cell in sim.cells:
        d = [d for d in tel.decisions if d.cell == cell.name][-1]
        row = per_cell[cell.name]
        print(f"  [{cell.name:8s}] split={d.new_split} {d.transport:13s} "
              f"p50 {row['latency_p50_ms']:7.2f} ms  "
              f"energy {row['mean_mobile_energy_mj']:5.1f} mJ")
    f = tel.fairness()
    print(f"  fairness: max/min {f['max_min_latency_ratio']:.2f}x, "
          f"Jain {f['jain_index']:.3f} "
          "(per-cell controllers diverge on their own conditions)")


if __name__ == "__main__":
    resnet_sweep()
    transformer_sweep()
    runtime_closed_loop()
    topology_closed_loop()
