"""Real numerics + analytic timing for partitioned execution.

Numerics and time are decoupled on purpose: the jax computation produces the
actual logits/tokens/caches (so split serving is verifiable against the
single-mesh forward), while durations come from the roofline
cost model (core/profiler) driven by the deterministic virtual clock — a
CPU-only container can therefore simulate a Jetson-class edge talking to a
GPU-class cloud over 3G with reproducible traces.

The cloud hosts the paper's "M partitioned models" (Sec. III-C) as ONE
shared backbone parameter tree: :class:`SplitModelBank` initialises the
model once and every candidate split's edge/cloud halves slice the stacked
layer params in-graph (``models/transformer.slice_stage_params``), so bank
memory stays O(1) in the number of hosted splits and only the tiny
per-split butterfly projections are materialised per candidate.
:class:`SplitRunner` is a thin facade over the bank's compile cache: jitted
edge/cloud/prefill/decode functions are keyed on ``(kind, split)`` with
bucket-padded ``(B, S)`` shapes, so a candidate sweep re-uses executables
instead of recompiling per prompt length.  The int8 wire runs through the
fused Pallas reduce+quant / dequant+restore kernels (kernels/ops.py).

Multi-token requests pick a decode transport (runtime/transports.py):
``cache_handoff`` ships the edge stage-0 KV cache to the cloud alongside the
codes (prefill/decode-disaggregation style cache transfer) so decode runs
entirely cloud-side; ``streamed`` keeps the stage-0 cache on the edge and
streams one fused-quantized ``(1, d_r)`` row per generated token through the
butterfly (DESIGN.md section 8.6) — the bank's compile cache grows per-token
``edge_step``/``cloud_step`` entries for it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core import costs
from repro.core.planner import wire_mode_bytes
from repro.core.profiler import HardwareProfile


def act_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def input_bytes(cfg, seq: int) -> float:
    """Cloud-only offload ships the frontend's feature output (the paper
    ships the raw 224x224x3 image) — one d_model-wide row per position."""
    return float(seq * cfg.d_model * act_bytes(cfg))


# ---------------------------------------------------------------------------
# analytic timing (virtual-clock durations)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostModel:
    """``edge_mp``/``cloud_mp`` — model-axis degree each half's stage is
    sharded over (DESIGN.md section 11): per-stage estimates divide by the
    degree via :func:`costs.model_parallel_share` (heterogeneous fleets run
    edge_mp=1 against a wide cloud)."""
    cfg: object
    edge: HardwareProfile
    cloud: HardwareProfile
    edge_mp: int = 1
    cloud_mp: int = 1

    def _where(self, where: str):
        if where == "edge":
            return self.edge, self.edge_mp
        return self.cloud, self.cloud_mp

    def _roofline(self, hw: HardwareProfile, flops: float,
                  load: float = 0.0, mp: int = 1) -> float:
        nbytes = flops / max(self.cfg.d_model, 1)      # planner's bytes proxy
        flops, nbytes = costs.model_parallel_share((flops, nbytes), mp)
        return hw.latency_s(flops, nbytes) / max(1e-9, 1.0 - load)

    def edge_prefill_s(self, split: int, seq: int, d_r: int) -> float:
        f = costs.stack_flops(self.cfg, seq, 0, split)
        f += 2 * seq * self.cfg.d_model * d_r          # reduction unit
        return self._roofline(self.edge, f, mp=self.edge_mp)

    def cloud_prefill_s(self, split: int, seq: int, d_r: int,
                        load: float = 0.0) -> float:
        f = costs.stack_flops(self.cfg, seq, split, self.cfg.num_layers)
        f += 2 * seq * d_r * self.cfg.d_model          # restoration unit
        f += costs.embed_flops(self.cfg, seq)
        return self._roofline(self.cloud, f, load, mp=self.cloud_mp)

    def full_prefill_s(self, seq: int, *, where: str,
                       load: float = 0.0) -> float:
        f = costs.stack_flops(self.cfg, seq, 0, self.cfg.num_layers)
        f += costs.embed_flops(self.cfg, seq)
        hw, mp = self._where(where)
        return self._roofline(hw, f, load, mp=mp)

    def decode_step_s(self, batch: int, *, where: str,
                      load: float = 0.0) -> float:
        # decode is weight-bound: every step streams the full parameter set
        hw, mp = self._where(where)
        f, nbytes = costs.model_parallel_share(
            costs.full_decode_step_cost(self.cfg, batch), mp)
        return hw.latency_s(f, nbytes) / max(1e-9, 1.0 - load)

    def edge_energy_mj(self, seconds: float) -> float:
        return seconds * self.edge.compute_power_w * 1e3

    def edge_decode_step_s(self, split: int, d_r: int) -> float:
        """One streamed-decode edge step: embed + layers [0, split) +
        reduce/quantize for a single token."""
        f, b = costs.model_parallel_share(
            costs.edge_decode_step_cost(self.cfg, split, d_r), self.edge_mp)
        return self.edge.latency_s(f, b)

    def cloud_decode_step_s(self, split: int, d_r: int, batch: int = 1,
                            load: float = 0.0) -> float:
        """One streamed-decode cloud turn: restore + layers [split, N) +
        unembed for ``batch`` arrived rows."""
        f, b = costs.model_parallel_share(
            costs.cloud_decode_step_cost(self.cfg, split, d_r, batch),
            self.cloud_mp)
        return self.cloud.latency_s(f, b) / max(1e-9, 1.0 - load)

    def stream_row_bytes(self, wire_mode: str, d_r: int) -> float:
        """Per-token uplink bytes of the streamed transport: one boundary
        row in the wire format (int8 codes + f32 scale for the paper's
        mode; "int4" nibble-packs two codes per byte, halving the code
        bytes)."""
        return wire_mode_bytes(self.cfg, 1, d_r, wire_mode)

    def serial_decode_tick_s(self, split: int, d_r: int, *,
                             wire_mode: str = "int8",
                             link_bps: Optional[float] = None,
                             batch: int = 1, load: float = 0.0) -> float:
        """Per-token latency of serial ping-pong decode: the edge step, the
        wire row and the cloud step run strictly in sequence, so one pod
        always idles."""
        t = self.edge_decode_step_s(split, d_r) + \
            self.cloud_decode_step_s(split, d_r, batch, load)
        if link_bps:
            t += self.stream_row_bytes(wire_mode, d_r) * 8.0 / link_bps
        return t

    def pipelined_decode_tick_s(self, split: int, d_r: int, *,
                                wire_mode: str = "int8",
                                link_bps: Optional[float] = None,
                                batch: int = 1, load: float = 0.0) -> float:
        """Steady-state per-token cadence of pipelined decode (>= 2
        in-flight microbatches rotating through the 2-pod mesh): the edge
        step for microbatch k+1, the wire row and the cloud step for
        microbatch k all overlap, so the tick is the slowest part instead
        of the sum."""
        parts = [self.edge_decode_step_s(split, d_r),
                 self.cloud_decode_step_s(split, d_r, batch, load)]
        if link_bps:
            parts.append(self.stream_row_bytes(wire_mode, d_r) * 8.0
                         / link_bps)
        return max(parts)

    def payload_bytes(self, mode: str, wire_mode: str, seq: int,
                      d_r: int, split: int, new_tokens: int = 1,
                      transport: str = "cache_handoff") -> float:
        """Prefill uplink bytes per request.  Split requests generating more
        than one token additionally ship the edge stage-0 KV cache under the
        ``cache_handoff`` decode transport (counted honestly); the
        ``streamed`` transport keeps that cache on the edge and pays one
        ``stream_row_bytes`` row per later token instead."""
        if mode == "cloud":
            return input_bytes(self.cfg, seq)
        if mode == "edge":
            return 0.0
        b = wire_mode_bytes(self.cfg, seq, d_r, wire_mode)
        if new_tokens > 1 and transport == "cache_handoff":
            b += self.stage0_cache_bytes(seq, split)
        return b

    def stage0_cache_bytes(self, seq: int, split: int) -> float:
        """KV bytes of the edge stage's ``split`` layers (the cache-handoff
        uplink term) — the arch formula lives in :func:`costs.kv_cache_bytes`."""
        return costs.kv_cache_bytes(self.cfg, seq, split)


# ---------------------------------------------------------------------------
# real numerics: one shared backbone, per-split views
# ---------------------------------------------------------------------------


def _next_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class SplitModelBank:
    """One backbone parameter tree serving every candidate split.

    The paper's server hosts M partitioned models and the selection phase
    picks among them; here the M models are in-graph slices of a single
    stacked parameter set, so materialising more candidates costs only the
    per-split butterfly projections (d*d_r + d_r*d params each) plus compile
    cache entries — not O(num_layers) full parameter copies.

    ``edge_mp``/``cloud_mp`` set the default model-axis degree each half's
    jitted functions run at (DESIGN.md section 11): degree > 1 wraps the
    half in a shard_map over a ``("model",)`` sub-mesh of the first N local
    devices with attention heads / d_ff / experts sharded tensor-parallel
    and kv caches kept as per-rank head slices.  Runners may override per
    half (heterogeneous edge=1, cloud=N), and the compile cache keys on the
    mesh shape — two meshes on one bank never share a jitted step."""

    def __init__(self, base_cfg, d_r: int, *, wire_bits: int = 8,
                 wire_mode: str = "int8", seed: int = 0,
                 edge_mp: int = 1, cloud_mp: int = 1, profiler=None):
        import jax
        import jax.numpy as jnp

        from repro.models import model as M
        from repro.models import transformer as tfm

        assert base_cfg.num_layers >= 2, "need >=2 layers to split"
        # "entropy" is numerically int8 — the rANS coding of the codes is
        # lossless, so the in-graph halves are shared with the int8 wire and
        # only byte accounting / transport choreography differ (wire_codec)
        assert wire_mode in ("raw", "reduced", "int8", "int4", "entropy"), \
            wire_mode
        if wire_mode == "int4":
            assert d_r % 2 == 0, "int4 wire packs two codes per byte"
        if base_cfg.butterfly is not None:
            import dataclasses
            base_cfg = dataclasses.replace(base_cfg, butterfly=None)
        self.base_cfg = base_cfg
        self.d_r = d_r
        self.wire_bits = wire_bits
        self.wire_mode = wire_mode
        self.seed = seed
        self.edge_mp = int(edge_mp)
        self.cloud_mp = int(cloud_mp)
        self._meshes: Dict[int, object] = {}          # mp -> ("model",) Mesh

        # THE one backbone init (regardless of how many splits materialize)
        self.built = M.build(base_cfg)
        self.params, _ = M.init_model(jax.random.key(seed), self.built)
        self._M, self._tfm = M, tfm
        self._dt = jnp.dtype(base_cfg.dtype)
        self._defs = tfm.build_layer_defs(base_cfg)

        # seq bucketing is only numerics-preserving when padded tail rows
        # cannot leak into real rows: pure causal global attention.  Windowed
        # ring caches, SSM/xLSTM recurrent state and MoE capacity contention
        # all observe the padding, so those families compile per exact shape.
        self._seq_bucket_ok = (not base_cfg.is_encdec and all(
            d.mixer == "attn" and d.window is None and not d.cross
            for d in self._defs))
        # batch rows are independent everywhere except MoE (shared capacity);
        # the actors also consult this before coalescing request numerics
        self._batch_bucket_ok = all(d.ffn != "moe" for d in self._defs)
        # effective wire precision: "int4" quantizes to 4-bit codes (packed
        # two per byte outside the kernel) regardless of the config default
        self.wire_eff_bits = 4 if wire_mode == "int4" else wire_bits
        # the fused Pallas codec emits int8 codes, which covers every
        # sub-byte precision too (packing happens outside the kernel); only
        # wider wires (wire_bits=16 -> int16 codes) take the eager path
        self._kernel_wire_ok = self.wire_eff_bits <= 8
        # decode-row kernel block size, derived ONCE from the wire format
        # instead of per call, and folded into every compile-cache key so
        # int4 and int8 rows (same (B, S) buckets, different packed widths)
        # never alias a jitted step
        from repro.kernels import ops as _kops
        self.row_block = _kops.decode_row_block()
        self._wire_sig = (wire_mode, self.wire_eff_bits, self.row_block)

        self._butterfly: Dict[int, dict] = {}
        # runner key: (split, edge_mp, cloud_mp); fn key: (kind, split, mp) —
        # the mesh shape is part of the compile-cache key, so two meshes on
        # one bank never alias a jitted step (and the engine's weak-keyed
        # sampling-step cache sees distinct closures per mesh)
        self._runners: Dict[Tuple[int, int, int], "SplitRunner"] = {}
        self._fns: Dict[Tuple[str, int, int], object] = {}  # compile cache
        self._cache_templates: Dict[Tuple[int, int, int, int], object] = {}
        # (kind, split, mp, B_bkt, S_bkt) + wire signature
        self.jit_cache_keys: set = set()
        # opt-in wall-clock attribution (metrics.JitProfiler) + hit/miss
        # bookkeeping per padded-shape cache entry
        self.profiler = profiler
        self.cache_hits = 0
        self.cache_misses = 0

    # ------------------------------------------------------------------ api
    @property
    def candidates(self) -> Tuple[int, ...]:
        return tuple(range(1, self.base_cfg.num_layers))

    @property
    def jit_cache_entries(self) -> int:
        return len(self.jit_cache_keys)

    def cache_key(self, kind: str, split: int, mp: int, B: int,
                  S: int) -> Tuple:
        """Compile-cache key for one hot-path dispatch: the padded shape
        bucket plus the wire signature (mode, effective bits, decode-row
        kernel block) so differently-packed wires never alias."""
        return (kind, split, mp, B, S) + self._wire_sig

    def timed_call(self, key: Tuple, fn, *args):
        """Run one hot-path dispatch, recording its compile-cache key (hit
        or miss per padded-shape entry) and — when a profiler is attached —
        its wall-clock first-call/steady attribution."""
        self.note_key(key)
        if self.profiler is None:
            return fn(*args)
        return self.profiler.timed(key, fn, *args)

    def note_key(self, key: Tuple) -> None:
        """Hit/miss bookkeeping only — for dispatches whose jitted call runs
        elsewhere (the engine's fused sampling steps)."""
        if key in self.jit_cache_keys:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
            self.jit_cache_keys.add(key)

    @property
    def batch_numerics_ok(self) -> bool:
        """Whether independent requests may be stacked into one batch
        without changing any request's numerics (False for MoE, whose
        expert-capacity pool couples the batch)."""
        return self._batch_bucket_ok

    def runner(self, split: int, *, edge_mp: Optional[int] = None,
               cloud_mp: Optional[int] = None) -> "SplitRunner":
        """Facade for one candidate split; ``edge_mp``/``cloud_mp`` override
        the bank defaults so heterogeneous halves (edge=1, cloud=N) share
        the same backbone."""
        from repro.models import transformer as tfm
        edge_mp = self.edge_mp if edge_mp is None else int(edge_mp)
        cloud_mp = self.cloud_mp if cloud_mp is None else int(cloud_mp)
        key = (split, edge_mp, cloud_mp)
        if key not in self._runners:
            assert 0 < split < self.base_cfg.num_layers, split
            for mp in {edge_mp, cloud_mp}:
                tfm.check_tp_divisibility(self._defs, self.base_cfg, mp)
            self._runners[key] = SplitRunner(self, split, edge_mp=edge_mp,
                                             cloud_mp=cloud_mp)
        return self._runners[key]

    def mp_mesh(self, mp: int):
        """The ``("model",)`` sub-mesh of degree ``mp`` over the first mp
        local devices (None for degree 1 — the plain-jit path)."""
        if mp <= 1:
            return None
        if mp not in self._meshes:
            import jax
            import numpy as np
            assert len(jax.devices()) >= mp, \
                f"model-axis degree {mp} needs >= {mp} devices " \
                f"(have {len(jax.devices())}; set " \
                f"--xla_force_host_platform_device_count on CPU)"
            self._meshes[mp] = jax.sharding.Mesh(
                np.array(jax.devices()[:mp]), ("model",))
        return self._meshes[mp]

    def _pctx(self, mp: int):
        from repro.models.parallel import manual_context
        return manual_context(self.mp_mesh(mp))

    def butterfly_params(self, split: int) -> dict:
        if split not in self._butterfly:
            import jax
            from repro.core.butterfly import init_butterfly
            from repro.configs.base import ButterflyConfig
            key = jax.random.fold_in(jax.random.key(self.seed), split)
            bf = ButterflyConfig(layer=split, d_r=self.d_r,
                                 wire_bits=self.wire_eff_bits)
            self._butterfly[split], _ = init_butterfly(
                key, self.base_cfg.d_model, bf, self._dt)
        return self._butterfly[split]

    # ----------------------------------------------------- bucketing helpers
    def _buckets(self, B: int, S: int) -> Tuple[int, int]:
        Bb = _next_bucket(B, 1) if self._batch_bucket_ok else B
        Sb = _next_bucket(S, 16) if self._seq_bucket_ok else S
        return Bb, Sb

    def _pad_toks(self, toks, Bb: int, Sb: int):
        import jax.numpy as jnp
        toks = jnp.asarray(toks)
        B, S = toks.shape
        if (B, S) != (Bb, Sb):
            toks = jnp.pad(toks, ((0, Bb - B), (0, Sb - S)))
        return toks

    def _cache_template(self, stage: int, split: int, B: int, S: int):
        """ShapeDtypeStruct tree of stage ``stage``'s range cache at true
        (B, S) — used to slice bucket-padded caches back to request shape.
        Cached per instance (an lru_cache on the method would pin the bank —
        and its full backbone — in a class-level cache forever)."""
        import jax
        key = (stage, split, B, S)
        if key not in self._cache_templates:
            lo, hi = (0, split) if stage == 0 else (split,
                                                    self.base_cfg.num_layers)
            segs = self._tfm.range_segments(list(self.built.stages[0]),
                                            lo, hi)
            self._cache_templates[key] = jax.eval_shape(
                lambda: self._tfm.init_stage_cache(segs, self.base_cfg,
                                                   B, S, self._dt))
        return self._cache_templates[key]

    def _slice_cache(self, cache, stage: int, split: int, B: int, S: int):
        import jax
        template = self._cache_template(stage, split, B, S)
        def cut(leaf, t):
            if leaf.shape == t.shape:
                return leaf
            return leaf[tuple(slice(0, s) for s in t.shape)]
        return jax.tree.map(cut, cache, template)

    def engine_stages(self, split: int):
        """Per-stage segmentations matching the range-sliced param views
        (the ServingEngine's cache-pool template for this split)."""
        segs = list(self.built.stages[0])
        return [self._tfm.range_segments(segs, 0, split),
                self._tfm.range_segments(segs, split,
                                         self.base_cfg.num_layers)]

    # ------------------------------------------------- wire transforms (jit)
    def _pack_wire(self, codes):
        """Wire-format packing of quantized codes: int4 nibble-packs two
        codes per byte (pack/unpack round-trips exactly, so the in-graph
        numerics are unchanged); every other mode ships codes as-is."""
        if self.wire_mode == "int4":
            from repro.core.quantization import pack_int4
            return pack_int4(codes)
        return codes

    def _unpack_wire(self, codes):
        if self.wire_mode == "int4":
            from repro.core.quantization import unpack_int4
            return unpack_int4(codes)
        return codes

    def _wire_ingraph(self, bf, x, *, use_kernel: bool):
        """The wire as the hosted model sees it, per wire_mode: raw ships the
        boundary tensor untouched, reduced projects down/up without
        quantization, int8/int4 round-trip the fused quantized codec (int4
        additionally round-trips the nibble packing)."""
        import jax.numpy as jnp
        from repro.core.quantization import dequantize, quantize
        if self.wire_mode == "raw":
            return x
        if self.wire_mode == "reduced":
            return (x @ bf["w_reduce"]) @ bf["w_restore"]
        if use_kernel and self._kernel_wire_ok:
            from repro.kernels import ops as kops
            codes, scales = kops.butterfly_reduce_quant(
                x, bf["w_reduce"], bits=self.wire_eff_bits)
            codes = self._unpack_wire(self._pack_wire(codes))
            return kops.butterfly_dequant_restore(
                codes, scales, bf["w_restore"], out_dtype=x.dtype)
        r = x @ bf["w_reduce"]
        codes, scales = quantize(r, self.wire_eff_bits)
        codes = self._unpack_wire(self._pack_wire(codes))
        return dequantize(codes, scales, x.dtype) @ bf["w_restore"]

    # --------------------------------------------------- jitted core factory
    def _fn(self, kind: str, split: int, mp: int = 1):
        key = (kind, split, mp) + self._wire_sig
        if key not in self._fns:
            self._fns[key] = getattr(self, f"_make_{kind}")(split, mp)
        return self._fns[key]

    def _stage_ctx(self, mp: int = 1):
        from repro.models.common import embed, rms_norm, unembed
        cfg = self.base_cfg
        segs = list(self.built.stages[0])
        scale = cfg.arch_type == "dense" and cfg.act == "gelu"
        return cfg, segs, scale, embed, rms_norm, unembed, self._pctx(mp)

    def _tp_specs(self):
        if not hasattr(self, "_tp_specs_tree"):
            self._tp_specs_tree = self._M.tp_param_specs(self.built,
                                                         with_butterfly=True)
        return self._tp_specs_tree

    def _cache_spec_tree(self, stage: int, split: int):
        """Spec tree of stage ``stage``'s range cache under a model mesh:
        attention kv-head dims shard with their head slice; recurrent state
        replicates."""
        return self._tfm.stage_cache_spec(self.engine_stages(split)[stage],
                                          None, None, head_axis="model")

    def _mp_wrap(self, fn, mp: int, specs):
        """shard_map ``fn`` over the degree-``mp`` model mesh (identity for
        mp == 1, keeping single-degree callers on the exact plain-jit path).
        ``specs`` is a zero-arg callable returning ``(in_specs, out_specs)``
        — invoked only when a real mesh exists, because tensor-parallel spec
        construction asserts arch support (e.g. no enc-dec) and must never
        fire for degree-1 callers."""
        mesh = self.mp_mesh(mp)
        if mesh is None:
            return fn
        from repro import compat
        in_specs, out_specs = specs()
        return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=False)

    def _make_edge(self, split: int, mp: int = 1):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        cfg, segs, scale, embed, _, _, pctx = self._stage_ctx(mp)
        tfm, wm = self._tfm, self.wire_mode

        def edge(params, toks):
            x = embed(params["embed"], toks, scale=scale)
            x, cache0, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, 0, split, cfg=cfg, pctx=pctx,
                mode="prefill", range_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            if wm == "raw":
                return x, jnp.zeros((*x.shape[:2], 1), jnp.float32), cache0
            if wm == "reduced":
                r = x @ params["butterfly"]["w_reduce"]
                return r, jnp.zeros((*r.shape[:2], 1), jnp.float32), cache0
            if self._kernel_wire_ok:
                codes, scales = kops.butterfly_reduce_quant(
                    x, params["butterfly"]["w_reduce"],
                    bits=self.wire_eff_bits)
            else:
                from repro.core.quantization import quantize
                codes, scales = quantize(x @ params["butterfly"]["w_reduce"],
                                         self.wire_eff_bits)
            return self._pack_wire(codes), scales, cache0

        edge = self._mp_wrap(
            edge, mp, lambda: ((self._tp_specs(), P()),
                               (P(), P(), self._cache_spec_tree(0, split))))
        return jax.jit(edge)

    def _make_cloud(self, split: int, mp: int = 1):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        cfg, segs, _, _, rms_norm, unembed, pctx = self._stage_ctx(mp)
        tfm, wm, dt = self._tfm, self.wire_mode, self._dt

        def cloud(params, payload, scales, length):
            if wm == "raw":
                x = payload
            elif wm == "reduced":
                x = payload @ params["butterfly"]["w_restore"]
            elif self._kernel_wire_ok:
                x = kops.butterfly_dequant_restore(
                    self._unpack_wire(payload), scales,
                    params["butterfly"]["w_restore"], out_dtype=dt)
            else:
                from repro.core.quantization import dequantize
                x = dequantize(self._unpack_wire(payload), scales, dt) @ \
                    params["butterfly"]["w_restore"]
            x, cache1, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, split, cfg.num_layers, cfg=cfg,
                pctx=pctx, mode="prefill", range_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
            x = rms_norm(x, params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x, cfg.logit_softcap)[:, 0], cache1

        cloud = self._mp_wrap(
            cloud, mp, lambda: ((self._tp_specs(), P(), P(), P()),
                                (P(), self._cache_spec_tree(1, split))))
        return jax.jit(cloud)

    def _make_prefill(self, split: int, mp: int = 1):
        """Full hosted-model prefill (both halves + the wire, one graph):
        the engine path for cloud-only / mobile-only serving."""
        import jax
        from jax.sharding import PartitionSpec as P
        cfg, segs, scale, embed, rms_norm, unembed, pctx = self._stage_ctx(mp)
        tfm = self._tfm

        def prefill(params, toks, length):
            x = embed(params["embed"], toks, scale=scale)
            x, cache0, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, 0, split, cfg=cfg, pctx=pctx,
                mode="prefill", range_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = self._wire_ingraph(params["butterfly"], x, use_kernel=True)
            x, cache1, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, split, cfg.num_layers, cfg=cfg,
                pctx=pctx, mode="prefill", range_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
            x = rms_norm(x, params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x, cfg.logit_softcap), [cache0, cache1]

        prefill = self._mp_wrap(
            prefill, mp,
            lambda: ((self._tp_specs(), P(), P()),
                     (P(), [self._cache_spec_tree(0, split),
                            self._cache_spec_tree(1, split)])))
        return jax.jit(prefill)

    def _make_decode(self, split: int, mp: int = 1):
        """Batched hosted-model decode step for the ServingEngine: fixed
        (max_batch, 1) shapes, ragged per-slot positions, the wire via the
        fused kernels' (B, 1, d) fast path.  NOT jit-wrapped here — the
        engine folds sampling into the same jitted step."""
        from jax.sharding import PartitionSpec as P
        cfg, segs, scale, embed, rms_norm, unembed, pctx = self._stage_ctx(mp)
        tfm = self._tfm

        def decode(params, tokens, caches, pos):
            x = embed(params["embed"], tokens, scale=scale)
            x, nc0, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, 0, split, cfg=cfg, pctx=pctx,
                mode="decode", range_cache=caches[0], pos=pos,
                shared_params=params.get("shared_attn"))
            x = self._wire_ingraph(params["butterfly"], x, use_kernel=True)
            x, nc1, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, split, cfg.num_layers, cfg=cfg,
                pctx=pctx, mode="decode", range_cache=caches[1], pos=pos,
                shared_params=params.get("shared_attn"))
            x = rms_norm(x, params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x, cfg.logit_softcap), [nc0, nc1]

        def specs():
            cache_specs = [self._cache_spec_tree(0, split),
                           self._cache_spec_tree(1, split)]
            return ((self._tp_specs(), P(), cache_specs, P()),
                    (P(), cache_specs))

        return self._mp_wrap(decode, mp, specs)

    def _make_edge_step(self, split: int, mp: int = 1):
        """Streamed-decode edge half: embed one token, run layers [0, split)
        against the edge-resident stage-0 decode cache, emit one wire row —
        the per-token payload that replaces the stage-0 cache handoff."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        cfg, segs, scale, embed, _, _, pctx = self._stage_ctx(mp)
        tfm, wm = self._tfm, self.wire_mode

        def edge_step(params, tok, cache0, pos):
            x = embed(params["embed"], tok, scale=scale)
            x, nc0, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, 0, split, cfg=cfg, pctx=pctx,
                mode="decode", range_cache=cache0, pos=pos,
                shared_params=params.get("shared_attn"))
            if wm == "raw":
                return x, jnp.zeros((*x.shape[:2], 1), jnp.float32), nc0
            if wm == "reduced":
                r = x @ params["butterfly"]["w_reduce"]
                return r, jnp.zeros((*r.shape[:2], 1), jnp.float32), nc0
            if self._kernel_wire_ok:
                codes, scales = kops.butterfly_reduce_quant(
                    x, params["butterfly"]["w_reduce"],
                    bits=self.wire_eff_bits)
            else:
                from repro.core.quantization import quantize
                codes, scales = quantize(x @ params["butterfly"]["w_reduce"],
                                         self.wire_eff_bits)
            return self._pack_wire(codes), scales, nc0

        def specs():
            spec0 = self._cache_spec_tree(0, split)
            return ((self._tp_specs(), P(), spec0, P()), (P(), P(), spec0))

        edge_step = self._mp_wrap(edge_step, mp, specs)
        return jax.jit(edge_step)

    def _make_cloud_step(self, split: int, mp: int = 1):
        """Streamed-decode cloud half: restore one arrived row and run layers
        [split, N) against the cloud-resident stage-1 decode cache.  NOT
        jit-wrapped here — the engine folds sampling into the same jitted
        step (serving/engine._sampled_stream_step), shared by every engine of
        this split."""
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        cfg, segs, _, _, rms_norm, unembed, pctx = self._stage_ctx(mp)
        tfm, wm, dt = self._tfm, self.wire_mode, self._dt

        def cloud_step(params, payload, scales, cache1, pos):
            if wm == "raw":
                x = payload
            elif wm == "reduced":
                x = payload @ params["butterfly"]["w_restore"]
            elif self._kernel_wire_ok:
                x = kops.butterfly_dequant_restore(
                    self._unpack_wire(payload), scales,
                    params["butterfly"]["w_restore"], out_dtype=dt)
            else:
                from repro.core.quantization import dequantize
                x = dequantize(self._unpack_wire(payload), scales, dt) @ \
                    params["butterfly"]["w_restore"]
            x, nc1, _ = tfm.apply_layer_range(
                segs, params["stages"][0], x, split, cfg.num_layers, cfg=cfg,
                pctx=pctx, mode="decode", range_cache=cache1, pos=pos,
                shared_params=params.get("shared_attn"))
            x = rms_norm(x, params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x, cfg.logit_softcap), nc1

        def specs():
            spec1 = self._cache_spec_tree(1, split)
            return ((self._tp_specs(), P(), P(), spec1, P()), (P(), spec1))

        return self._mp_wrap(cloud_step, mp, specs)


class SplitRunner:
    """Thin facade over the bank's shared backbone + compile cache for one
    candidate split.  ``runner.params`` shares every backbone leaf with
    ``bank.params`` (only the per-split butterfly differs).

    ``edge_mp``/``cloud_mp`` pick each half's model-axis degree: the edge
    half (edge/edge_step) and the cloud half (cloud/cloud_step, plus the
    full-model prefill/decode the cloud engines run) resolve through the
    bank's compile cache under their own mesh shape."""

    def __init__(self, bank: SplitModelBank, split: int, *, edge_mp: int = 1,
                 cloud_mp: int = 1):
        self.bank = bank
        self.split = split
        self.edge_mp = int(edge_mp)
        self.cloud_mp = int(cloud_mp)
        self.cfg = bank.base_cfg.with_butterfly(split, bank.d_r,
                                                bank.wire_eff_bits)
        self.wire_mode = bank.wire_mode
        self.built = bank.built
        # shallow dict: backbone leaves are bank.params' leaves, not copies
        self.params = dict(bank.params)
        self.params["butterfly"] = bank.butterfly_params(split)

    # ------------------------------------------------------------ split halves
    def edge_half(self, params, toks):
        """Edge stage: layers [0, split) + reduce + quantize.  Accepts
        (B, S) token batches; returns true-shape (payload, scales, cache0)
        — the jitted core runs at bucket-padded (B, S)."""
        import jax.numpy as jnp
        bank = self.bank
        toks = jnp.asarray(toks)
        B, S = toks.shape
        Bb, Sb = bank._buckets(B, S)
        out = bank.timed_call(
            bank.cache_key("edge", self.split, self.edge_mp, Bb, Sb),
            bank._fn("edge", self.split, self.edge_mp),
            params, bank._pad_toks(toks, Bb, Sb))
        payload, scales, cache0 = out
        return (payload[:B, :S], scales[:B, :S],
                bank._slice_cache(cache0, 0, self.split, B, S))

    def cloud_half(self, params, payload, scales):
        """Cloud stage: restore + layers [split, N) + LM head.  Returns
        (last-position logits (B, V), cache1)."""
        import jax.numpy as jnp
        bank = self.bank
        payload = jnp.asarray(payload)
        B, S = payload.shape[:2]
        Bb, Sb = bank._buckets(B, S)
        if (Bb, Sb) != (B, S):
            pad = ((0, Bb - B), (0, Sb - S), (0, 0))
            payload = jnp.pad(payload, pad)
            scales = jnp.pad(jnp.asarray(scales), pad)
        logits, cache1 = bank.timed_call(
            bank.cache_key("cloud", self.split, self.cloud_mp, Bb, Sb),
            bank._fn("cloud", self.split, self.cloud_mp),
            params, payload, scales, jnp.int32(S))
        return logits[:B], bank._slice_cache(cache1, 1, self.split, B, S)

    # --------------------------------------------------------- streamed decode
    def edge_step(self, params, tok, cache0, pos):
        """One streamed-decode edge step: ``tok`` (B, 1) int32, ``cache0``
        the edge-resident stage-0 decode cache (pad with
        :meth:`pad_decode_cache` first), ``pos`` (B,) int32 write positions.
        Returns ``(payload, scales, new_cache0)`` — one wire row per batch
        element."""
        import jax.numpy as jnp
        bank = self.bank
        tok = jnp.asarray(tok, jnp.int32)
        out = bank.timed_call(
            bank.cache_key("edge_step", self.split, self.edge_mp,
                           tok.shape[0], 1),
            bank._fn("edge_step", self.split, self.edge_mp),
            params, tok, cache0, jnp.asarray(pos, jnp.int32))
        return out

    def stream_step(self, engine, req, cache, payload, scales, pos: int):
        """One streamed-decode cloud turn through ``engine``'s single-slot
        entry, with the bank's compile-cache bookkeeping (mirrors
        :meth:`edge_step`).  Returns ``(token, new_cache)``."""
        out = engine.stream_step(req, cache, payload, scales, pos)
        self.bank.note_key(
            self.bank.cache_key("cloud_step", self.split, self.cloud_mp,
                                1, 1))
        return out

    def pad_decode_cache(self, cache, stage: int, length: int):
        """Pad a prefill-shaped (B=1, seq=S) stage cache to decode capacity
        ``length`` so per-token steps can write rows past the prompt —
        the streamed analogue of the engine pool's max_len sizing.  Leaves
        without a short seq axis (recurrent state) pass through."""
        import jax
        import jax.numpy as jnp
        template = self.bank._cache_template(stage, self.split, 1, length)

        def pad(leaf, t):
            if leaf.shape == t.shape:
                return leaf
            pads = [(0, ts - ls) for ls, ts in zip(leaf.shape, t.shape)]
            return jnp.pad(leaf, pads)

        return jax.tree.map(pad, cache, template)

    # ------------------------------------------------------ pipelined decode
    def decode_pipeline(self, mesh, num_microbatches: int, prompt_len: int,
                        microbatch: int, new_tokens: int, *,
                        pipelined: bool = True, use_kernel: bool = False,
                        overlap_psum: bool = False):
        """Multi-token greedy decode over a ``(pod, ...)`` mesh through this
        split: ``serving.pipeline.make_decode_pipeline``'s microbatch
        rotation (or its serial ping-pong reference with
        ``pipelined=False``) running the bank's shared backbone slices.
        Returns ``run(tokens) -> (num_microbatches * microbatch,
        new_tokens)`` greedy ids.  The compiled fn + split-view params are
        cached in the bank's compile cache under the wire signature."""
        import jax
        bank = self.bank
        assert bank.wire_mode in ("int8", "int4", "entropy"), \
            "decode pipeline wires quantized codes (int8/int4/entropy)"
        key = ("decode_pipeline", self.split, id(mesh), num_microbatches,
               prompt_len, microbatch, new_tokens, bool(pipelined),
               bool(use_kernel), bool(overlap_psum)) + bank._wire_sig
        if key not in bank._fns:
            from repro.models.model import BuiltModel
            from repro.serving import pipeline as spl
            tfm = bank._tfm
            segs = list(self.built.stages[0])
            N = bank.base_cfg.num_layers
            s0, p0 = tfm.slice_stage_params(segs, self.params["stages"][0],
                                            0, self.split)
            s1, p1 = tfm.slice_stage_params(segs, self.params["stages"][0],
                                            self.split, N)
            params = dict(self.params)
            params["stages"] = [p0, p1]
            built = BuiltModel(cfg=self.cfg, stages=(tuple(s0), tuple(s1)),
                               enc_segments=(),
                               long_mode=self.built.long_mode)
            fn = spl.make_decode_pipeline(
                built, mesh, num_microbatches, prompt_len, microbatch,
                new_tokens, wire_mode=bank.wire_mode, pipelined=pipelined,
                use_kernel=use_kernel, overlap_psum=overlap_psum)
            bank._fns[key] = (jax.jit(fn), params)
        fn, params = bank._fns[key]

        def run(tokens):
            bank.note_key(key)
            return fn(params, tokens)

        return run

    # ------------------------------------------------------------- engine glue
    def _engine_prefill(self, params, toks, mp: Optional[int] = None):
        import jax.numpy as jnp
        mp = self.cloud_mp if mp is None else mp
        bank = self.bank
        toks = jnp.asarray(toks)
        B, S = toks.shape
        Bb, Sb = bank._buckets(B, S)
        logits, caches = bank.timed_call(
            bank.cache_key("prefill", self.split, mp, Bb, Sb),
            bank._fn("prefill", self.split, mp),
            params, bank._pad_toks(toks, Bb, Sb), jnp.int32(S))
        return logits[:B], [bank._slice_cache(caches[0], 0, self.split, B, S),
                            bank._slice_cache(caches[1], 1, self.split, B, S)]

    def make_engine(self, *, max_batch: int, max_len: int, seed: int = 0,
                    mp: Optional[int] = None):
        """``mp`` — model-axis degree of the engine's whole-model
        prefill/decode steps.  Defaults to the runner's cloud degree (the
        engines live on the cloud server); the mobile-only baseline passes
        its edge degree so an edge-resident engine never compiles — or
        demands the devices of — the cloud's mesh."""
        from functools import partial

        from repro.serving.engine import ServingEngine
        mp = self.cloud_mp if mp is None else int(mp)
        return ServingEngine(self.params, self.built, max_batch=max_batch,
                             max_len=max_len, seed=seed,
                             stages=self.bank.engine_stages(self.split),
                             prefill_fn=partial(self._engine_prefill, mp=mp),
                             decode_fn=self.bank._fn("decode", self.split, mp),
                             stream_fn=self.bank._fn("cloud_step", self.split,
                                                     mp),
                             profiler=self.bank.profiler,
                             profile_key=(self.split, mp))

    # --------------------------------------------------------------- reference
    def reference_prefill(self, toks):
        """Single-mesh forward (what the split path must reproduce): eager,
        reference (non-kernel) wire codec, same wire_mode semantics."""
        import jax.numpy as jnp
        bank = self.bank
        cfg, segs, scale, embed, rms_norm, unembed, LOCAL = bank._stage_ctx()
        tfm = bank._tfm
        params = self.params
        x = embed(params["embed"], jnp.asarray(toks), scale=scale)
        x, cache0, _ = tfm.apply_layer_range(
            segs, params["stages"][0], x, 0, self.split, cfg=cfg, pctx=LOCAL,
            mode="prefill", range_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        x = bank._wire_ingraph(params["butterfly"], x, use_kernel=False)
        x, cache1, _ = tfm.apply_layer_range(
            segs, params["stages"][0], x, self.split, cfg.num_layers, cfg=cfg,
            pctx=LOCAL, mode="prefill", range_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(table, x, cfg.logit_softcap), [cache0, cache1]
