"""The Wire: a contended serial uplink over the core/wireless link models.

Any object exposing ``uplink_seconds(nbytes)`` / ``uplink_energy_mj(nbytes)``
(``WirelessNetwork`` from the paper's Table III, or the TPU ``Interconnect``)
backs an :class:`Uplink`.  The link is a FIFO pipe: when several edge devices
share it, a transfer waits until the link drains — that queueing delay is the
contention term that only appears at the request-stream level (JointDNN
Sec. V observes the same effect on shared cellular uplinks).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.wireless import get_link


@dataclass
class LinkStats:
    bytes_sent: float = 0.0
    busy_s: float = 0.0               # time the link actually transmitted
    wait_s: float = 0.0               # total contention wait across transfers
    energy_mj: float = 0.0            # mobile radio energy (paper power model)
    n_transfers: int = 0


class Uplink:
    """Serial FIFO link shared by a set of edge devices."""

    def __init__(self, link_model, name: Optional[str] = None):
        self.model = link_model
        self.name = name or getattr(link_model, "name", "link")
        self.free_at = 0.0
        self.stats = LinkStats()

    @classmethod
    def named(cls, name: str) -> "Uplink":
        return cls(get_link(name), name=name)

    def transfer_seconds(self, nbytes: float) -> float:
        return self.model.uplink_seconds(nbytes)

    def transfer(self, nbytes: float, now: float) -> Tuple[float, float]:
        """Enqueue ``nbytes`` at virtual time ``now``; returns
        ``(start, done)`` — ``start > now`` means the link was busy."""
        start = max(now, self.free_at)
        dur = self.transfer_seconds(nbytes)
        done = start + dur
        self.free_at = done
        s = self.stats
        s.bytes_sent += nbytes
        s.busy_s += dur
        s.wait_s += start - now
        s.energy_mj += self.model.uplink_energy_mj(nbytes)
        s.n_transfers += 1
        return start, done

    def nominal_bytes_per_s(self) -> float:
        return 1.0 / max(self.model.uplink_seconds(1.0), 1e-30)

    def observed_bytes_per_s(self, now: float) -> float:
        """Effective per-request goodput including contention waits — what a
        device actually experiences, and what the adaptive controller feeds
        back into the selection phase."""
        s = self.stats
        occupied = s.busy_s + s.wait_s
        if s.n_transfers == 0 or occupied <= 0:
            return self.nominal_bytes_per_s()
        return s.bytes_sent / occupied

    def transfer_energy_mj(self, nbytes: float) -> float:
        return self.model.uplink_energy_mj(nbytes)
