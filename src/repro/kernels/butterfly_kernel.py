"""Pallas TPU kernel for the butterfly hot path: fused reduction projection +
int8 wire quantization (and the mirror dequant + restoration).

Why fuse: on the edge stage the reduced tensor (T, d_r) would otherwise make
an HBM round trip between the matmul and the quantizer; fusing keeps it in
VMEM, and the only HBM writes are the int8 codes + f32 scales — exactly the
bytes that cross the pod boundary.  Token-tiled: each grid step loads a
(TM, d) x-tile and the full (d, d_r) weight (d_r << d, so the weight tile is
small), runs the MXU matmul at f32 accumulation, then the absmax/scale/round
epilogue in-register.

TM defaults to 256 rows; d and d_r are padded to the 128-lane boundary by
the ops.py wrapper so MXU dims stay hardware-aligned.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_quant_kernel(x_ref, w_ref, codes_ref, scales_ref, *, qmax: int):
    x = x_ref[...]
    w = w_ref[...]
    r = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (TM, d_r) f32, MXU
    absmax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(r / scale), -qmax - 1, qmax)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale


def butterfly_reduce_quant_kernel(x, w_reduce, *, bits: int = 8,
                                  block_t: int = 256,
                                  interpret: bool = False):
    """x: (T, d), w_reduce: (d, d_r); T % block_t == 0, dims 128-aligned."""
    T, d = x.shape
    d_r = w_reduce.shape[1]
    assert T % block_t == 0, (T, block_t)
    qmax = 2 ** (bits - 1) - 1
    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_reduce_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d_r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, d_r), jnp.int8),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_reduce)


def _reduce_quant_bincount_kernel(x_ref, w_ref, codes_ref, scales_ref,
                                  counts_ref, *, qmax: int, nsym: int):
    """Reduce+quant epilogue plus a per-channel symbol histogram, accumulated
    across the token grid into a single fixed-index (d_r, nsym) output — the
    codes never leave VMEM between quantization and counting, so the edge
    gets its entropy estimate for free in the same pass."""
    x = x_ref[...]
    w = w_ref[...]
    r = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (TM, d_r) f32, MXU
    absmax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(r / scale), -qmax - 1, qmax)
    codes_ref[...] = codes.astype(jnp.int8)
    scales_ref[...] = scale

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sym = codes.astype(jnp.int32) + (qmax + 1)            # (TM, d_r) in [0, nsym)
    ks = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nsym), 2)
    onehot = (sym[:, :, None] == ks).astype(jnp.int32)
    counts_ref[...] += jnp.sum(onehot, axis=0)            # (d_r, nsym)


def butterfly_reduce_quant_bincount_kernel(x, w_reduce, *, bits: int = 8,
                                           block_t: int = 256,
                                           interpret: bool = False):
    """x: (T, d), w_reduce: (d, d_r); T % block_t == 0.  Returns
    (codes (T, d_r) int8, scales (T, 1) f32, counts (d_r, 2**bits) int32)."""
    T, d = x.shape
    d_r = w_reduce.shape[1]
    assert T % block_t == 0, (T, block_t)
    qmax = 2 ** (bits - 1) - 1
    nsym = 1 << bits
    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_reduce_quant_bincount_kernel, qmax=qmax, nsym=nsym),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d_r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_r, nsym), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, d_r), jnp.int8),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
            jax.ShapeDtypeStruct((d_r, nsym), jnp.int32),
        ],
        interpret=interpret,
    )(x, w_reduce)


def _dequant_restore_norm_kernel(codes_ref, scales_ref, w_ref, nw_ref,
                                 x_ref, h_ref, *, eps: float):
    """Dequant + restore matmul + the first cloud layer's input RMSNorm in
    one VMEM residency: the restored activation never round-trips HBM
    before the layer consumes its normed copy.  The norm mirrors
    models.common.rms_norm bitwise — including the round-trip through the
    output dtype between restore and norm, so fused == unfused exactly."""
    r = codes_ref[...].astype(jnp.float32) * scales_ref[...]
    w = w_ref[...]
    out = jax.lax.dot_general(
        r, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    x = out.astype(x_ref.dtype)
    x_ref[...] = x
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    h_ref[...] = (normed * (1.0 + nw_ref[...].astype(jnp.float32))
                  ).astype(h_ref.dtype)


def butterfly_dequant_restore_norm_kernel(codes, scales, w_restore, norm_w, *,
                                          eps: float = 1e-6,
                                          out_dtype=jnp.float32,
                                          block_t: int = 256,
                                          interpret: bool = False):
    """codes: (T, d_r) int8, scales: (T, 1), w_restore: (d_r, d),
    norm_w: (1, d) -> (x (T, d), h (T, d)): the restored activation and its
    RMSNormed copy (the first cloud layer's norm1 input)."""
    T, d_r = codes.shape
    d = w_restore.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (T // block_t,)
    return pl.pallas_call(
        functools.partial(_dequant_restore_norm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d_r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_r, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, d), out_dtype),
            jax.ShapeDtypeStruct((T, d), out_dtype),
        ],
        interpret=interpret,
    )(codes, scales, w_restore, norm_w)


def _dequant_restore_kernel(codes_ref, scales_ref, w_ref, out_ref):
    r = codes_ref[...].astype(jnp.float32) * scales_ref[...]
    w = w_ref[...]
    out = jax.lax.dot_general(
        r, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


def butterfly_dequant_restore_kernel(codes, scales, w_restore, *,
                                     out_dtype=jnp.float32,
                                     block_t: int = 256,
                                     interpret: bool = False):
    """codes: (T, d_r) int8, scales: (T, 1), w_restore: (d_r, d) -> (T, d)."""
    T, d_r = codes.shape
    d = w_restore.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (T // block_t,)
    return pl.pallas_call(
        _dequant_restore_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d_r), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), out_dtype),
        interpret=interpret,
    )(codes, scales, w_restore)
