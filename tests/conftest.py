import os
import sys

import pytest

# src layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: never set xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the 512-device mesh belongs to launch/dryrun.py
# only, and the pipeline test spawns a subprocess with its own flags).

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_report_header(config):
    return f"jax {jax.__version__}, devices={jax.device_count()}"


def pytest_addoption(parser):
    parser.addoption(
        "--forbid-skips", action="store_true", default=False,
        help="turn every skipped test into a failure.  The CI multi-device "
             "job uses this so the sharded tests provably RUN instead of "
             "silently skipping on a 1-device runner.")


_FORBID_SKIPS = False


def pytest_configure(config):
    global _FORBID_SKIPS
    _FORBID_SKIPS = config.getoption("--forbid-skips")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    rep = outcome.get_result()
    if rep.skipped and item.config.getoption("--forbid-skips"):
        rep.outcome = "failed"
        rep.longrepr = (f"{item.nodeid}: skipped under --forbid-skips "
                        f"(original reason: {rep.longrepr})")


_COLLECT_SKIPS = []


def pytest_collectreport(report):
    # module/collection-level skips (pytest.importorskip, allow_module_level)
    # never reach pytest_runtest_makereport — without these hooks they would
    # green-skip straight past --forbid-skips
    if _FORBID_SKIPS and report.skipped:
        _COLLECT_SKIPS.append(f"{report.nodeid}: {report.longrepr}")


def pytest_terminal_summary(terminalreporter):
    for entry in _COLLECT_SKIPS:
        terminalreporter.write_line(
            f"collection skipped under --forbid-skips: {entry}", red=True)


def pytest_sessionfinish(session, exitstatus):
    if _COLLECT_SKIPS and session.exitstatus == 0:
        session.exitstatus = 1
