# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (us_per_call = wall time of the benchmarked call on this host;
# derived = the paper-comparable quantity).
#
#   fig7        accuracy vs D_r x split (tiny ResNet, synthetic images)
#   table4      per-split latency/energy profile via Algorithm 1 profiling
#   table5      selection phase on the paper's published Table IV -> exact
#               reproduction of the paper's chosen splits + improvements
#   sec3d       compression ratios (butterfly vs raw features)
#   wire        beyond-paper: pod-boundary wire bytes per arch
#   transport   decode-transport smoke: streamed vs cache-handoff parity
#   roofline    aggregated dry-run roofline table (reads experiments/dryrun)
#   micro       kernel/system microbenchmarks (us/call)
#
# Run: PYTHONPATH=src python -m benchmarks.run [names...]
from __future__ import annotations

import json
import os
import sys
import time


def _timeit(fn, n=3):
    fn()                                   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_fig7():
    """Fig. 7 miniature: accuracy for (split x D_r) on the synthetic task."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))
    from train_resnet_butterfly import train_and_eval
    from repro.configs.resnet50 import resnet50

    base = resnet50().reduced()
    t0 = time.perf_counter()
    target = train_and_eval(base, steps=60)
    rows = []
    for split in (1, 2):
        for d_r in (1, 4):
            acc = train_and_eval(base.with_butterfly(split, d_r), steps=60)
            rows.append((split, d_r, acc))
    us = (time.perf_counter() - t0) * 1e6
    for split, d_r, acc in rows:
        print(f"fig7/rb{split}_dr{d_r},{us/5:.0f},acc={acc:.3f}(target={target:.3f})")
    # the paper's qualitative claim: larger D_r never hurts
    for split in (1, 2):
        a1 = next(a for s, d, a in rows if s == split and d == 1)
        a4 = next(a for s, d, a in rows if s == split and d == 4)
        print(f"fig7/monotone_rb{split},0,larger_dr_better={a4 >= a1 - 0.05}")


def bench_table4():
    """Table IV analogue from the roofline profiler (full ResNet-50)."""
    from repro.configs.resnet50 import PAPER_MIN_DR, resnet50
    from repro.core import costs
    from repro.core.planner import TrainingPhaseResult, profiling_phase
    from repro.core.profiler import GTX_1080TI, JETSON_TX2
    from repro.core.wireless import NETWORKS

    cfg = resnet50()
    trained = [TrainingPhaseResult(s, PAPER_MIN_DR[s], 0.74) for s in range(1, 17)]

    def split_costs(split, d_r):
        ef, cf, wire = costs.resnet_split_flops(cfg, split, d_r)
        return ef, ef / 10, cf, cf / 10, wire

    t0 = time.perf_counter()
    profiles = profiling_phase(trained, split_costs, JETSON_TX2, GTX_1080TI)
    us = (time.perf_counter() - t0) * 1e6
    for p in profiles[:4] + profiles[7:8] + profiles[15:]:
        lat3g = p.latency(NETWORKS["3g"]) * 1e3
        latwifi = p.latency(NETWORKS["wifi"]) * 1e3
        print(f"table4/rb{p.split},{us/16:.0f},"
              f"wire={p.wire_bytes}B lat3g={lat3g:.2f}ms latwifi={latwifi:.2f}ms")


def bench_table5():
    """Selection phase on the paper's OWN published profile: must reproduce
    Table V exactly (RB8 for 3G, RB1 for 4G/Wi-Fi) + headline factors."""
    from repro.core.planner import select_from_table
    from repro.core.profiler import PAPER_CLOUD_ONLY, paper_profiles

    profs = paper_profiles()
    t0 = time.perf_counter()
    out = {}
    for net in ("3g", "4g", "wifi"):
        for obj in ("latency", "energy"):
            out[(net, obj)] = select_from_table(profs[net], obj)
    us = (time.perf_counter() - t0) * 1e6
    for net in ("3g", "4g", "wifi"):
        sel = out[(net, "latency")]
        row = profs[net][sel]
        lat_x = PAPER_CLOUD_ONLY[net][0] / row["latency_ms"]
        en_x = PAPER_CLOUD_ONLY[net][1] / row["energy_mj"]
        print(f"table5/{net},{us/6:.0f},split=RB{sel} lat_x={lat_x:.0f} "
              f"en_x={en_x:.0f} (paper: RB{'8' if net=='3g' else '1'})")
    avg_lat = sum(PAPER_CLOUD_ONLY[n][0] / profs[n][out[(n, 'latency')]]["latency_ms"]
                  for n in ("3g", "4g", "wifi")) / 3
    avg_en = sum(PAPER_CLOUD_ONLY[n][1] / profs[n][out[(n, 'energy')]]["energy_mj"]
                 for n in ("3g", "4g", "wifi")) / 3
    print(f"table5/headline,0,avg_lat_x={avg_lat:.0f}(paper=53) "
          f"avg_en_x={avg_en:.0f}(paper=68)")


def bench_sec3d():
    """Sec III-D compression ratios."""
    from repro.configs import get_config
    from repro.configs.all import ASSIGNED
    from repro.core.butterfly import compression_ratio
    # paper: RB1 reduces 256 -> 1 channels = 256x
    print(f"sec3d/resnet_rb1,0,compression={compression_ratio(256, 1, 8, 8):.0f}x"
          f"(paper=256x, prior art 3.3x)")
    for arch in ASSIGNED:
        cfg = get_config(arch)
        d_r = max(8, cfg.d_model // 64)
        c = compression_ratio(cfg.d_model, d_r, 16, 8)
        print(f"sec3d/{arch},0,d{cfg.d_model}->dr{d_r} wire_compression={c:.0f}x")


def bench_wire():
    """Beyond-paper: pod-boundary bytes for the split pipeline per arch."""
    from repro.configs import get_config
    from repro.serving.pipeline import wire_stats

    for arch in ("qwen3-8b", "gemma3-12b", "zamba2-7b", "xlstm-125m"):
        base = get_config(arch)
        cfg = base.with_butterfly(layer=max(1, base.num_layers // 8),
                                  d_r=max(16, base.d_model // 64))
        s = wire_stats(cfg, microbatch=8, seq=4096)
        print(f"wire/{arch},0,wire={s['wire_bytes']/1e6:.2f}MB "
              f"raw={s['raw_boundary_bytes']/1e6:.2f}MB "
              f"compression={s['compression']:.1f}x")


def bench_roofline():
    """Aggregate the dry-run artifacts into the section-Roofline table."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        print("roofline/none,0,run launch/dryrun first")
        return
    rows = []
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(d, f)))
        if "compute_s" not in rec:
            continue
        rows.append(rec)
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        print(f"roofline/{r['arch']}/{r['shape']},{r['compile_s']*1e6:.0f},"
              f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"bottleneck={r['bottleneck']} useful={r['useful_ratio']:.2f}")
    n_mp = sum(1 for r in rows if r["mesh"] == "2x16x16")
    print(f"roofline/multi_pod_compiles,0,count={n_mp}")


def bench_micro():
    """Microbenchmarks: butterfly kernel, flash attention, model forward."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.configs import get_config
    from repro.models import model as M

    x = jax.random.normal(jax.random.key(0), (1024, 512), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (512, 32), jnp.float32) * 0.05
    us = _timeit(lambda: jax.block_until_ready(
        ops.butterfly_reduce_quant(x, w)))
    print(f"micro/butterfly_reduce_quant_1024x512,{us:.0f},interpret_mode")

    q = jax.random.normal(jax.random.key(2), (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(3), (1, 256, 2, 64), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, k, block_q=128, block_k=128)))
    print(f"micro/flash_attention_256,{us:.0f},interpret_mode")

    cfg = get_config("qwen3-8b").reduced()
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)
    toks = jnp.ones((4, 128), jnp.int32)
    fwd = jax.jit(lambda p, t: M.forward_train(p, built, {"tokens": t})[0])
    us = _timeit(lambda: jax.block_until_ready(fwd(params, toks)))
    tokps = 4 * 128 / (us / 1e6)
    print(f"micro/reduced_qwen3_fwd_4x128,{us:.0f},tok_per_s={tokps:,.0f}")


def bench_wirebits():
    """Beyond-paper (the paper's stated future work: 'the extent of reduction
    ... can be explored'): trade accuracy vs wire precision.  Tiny LM +
    butterfly trained end-to-end with a 4/8/16-bit wire."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.quantization import wire_bytes
    from repro.data import lm_batches
    from repro.models import model as M
    from repro.training import (AdamWConfig, adamw_init, constant_schedule,
                                make_train_step)

    d_r = 16
    for bits in (4, 8, 16):
        cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                                  vocab_size=64)
        cfg = cfg.with_butterfly(layer=1, d_r=d_r, wire_bits=bits)
        built = M.build(cfg)
        params, _ = M.init_model(jax.random.key(0), built)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(
            built, AdamWConfig(lr=constant_schedule(3e-3))))
        import time as _t
        t0 = _t.perf_counter()
        last = None
        for i, raw in zip(range(60), lm_batches(cfg.vocab_size, 32, 8, seed=5)):
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, m = step(params, opt, batch)
            last = float(m["loss"])
        us = (_t.perf_counter() - t0) / 60 * 1e6
        wb = wire_bytes((8, 32, d_r), bits)
        print(f"wirebits/{bits}bit,{us:.0f},final_loss={last:.3f} "
              f"wire_bytes_per_batch={wb}")


def bench_bank():
    """Shared-weight split bank vs per-split model init (the tentpole's
    before/after): build time, parameter bytes and compile-cache entries for
    the full candidate sweep of an 8-layer config."""
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import model as M
    from repro.runtime.split_exec import SplitModelBank

    def tree_bytes(trees):
        seen, total = set(), 0
        for t in trees:
            for leaf in jax.tree.leaves(t):
                if id(leaf) not in seen:
                    seen.add(id(leaf))
                    total += leaf.nbytes
        return total

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), num_layers=8)
    d_r = 16
    splits = list(range(1, cfg.num_layers))

    # before: one full model init per candidate split (what SplitRunner did)
    t0 = time.perf_counter()
    naive_params = []
    for s in splits:
        scfg = cfg.with_butterfly(s, d_r)
        built = M.build(scfg)
        p, _ = M.init_model(jax.random.key(0), built)
        naive_params.append(p)
    naive_s = time.perf_counter() - t0
    naive_bytes = tree_bytes(naive_params)

    # after: one backbone + per-split butterfly views
    t0 = time.perf_counter()
    bank = SplitModelBank(cfg, d_r)
    runners = [bank.runner(s) for s in splits]
    bank_s = time.perf_counter() - t0
    bank_bytes = tree_bytes([r.params for r in runners])

    print(f"bank/build_naive,{naive_s*1e6:.0f},"
          f"{len(splits)}_inits bytes={naive_bytes/1e6:.1f}MB")
    print(f"bank/build_shared,{bank_s*1e6:.0f},"
          f"1_init+{len(splits)}_butterflies bytes={bank_bytes/1e6:.1f}MB")
    print(f"bank/reduction,0,build_time={naive_s/max(bank_s,1e-9):.1f}x "
          f"param_bytes={naive_bytes/bank_bytes:.1f}x")

    # compile-cache behaviour: a candidate sweep at one prompt length plus a
    # prompt-length sweep on one split — bucketing folds shapes together
    toks = np.ones((1, 16), np.int32)
    t0 = time.perf_counter()
    for r in runners:
        payload, scales, _ = r.edge_half(r.params, toks)
        r.cloud_half(r.params, payload, scales)
    sweep_entries = bank.jit_cache_entries
    seqs = (24, 31, 40)                  # 3 fresh shapes -> 2 seq buckets
    for S in seqs:
        runners[0].edge_half(runners[0].params, np.ones((1, S), np.int32))
    us = (time.perf_counter() - t0) * 1e6
    added = bank.jit_cache_entries - sweep_entries
    print(f"bank/jit_cache,{us/(len(splits)*2+len(seqs)):.0f},"
          f"entries_full_split_sweep={sweep_entries} "
          f"seq_sweep_{'_'.join(map(str, seqs))}_added={added} "
          f"(exact-shape compiles would add {len(seqs)})")


def _wire_codec_report():
    """Entropy-wire codec economics with the REAL rANS coder on held-out
    codes.  Both models branch off a shared 120-step rate-free prefix and
    take a second epoch over the same 120-batch shard — identical data,
    equal total steps: the baseline continues rate-free, the entropy
    branch adds the rate term to the loss (learn the task first, compress
    after — training with the rate term from step 0 lands on a much worse
    accuracy/rate frontier).  The prior is fit on the tail of the entropy
    branch's training shard and priced on held-out batches of the same
    Markov language."""
    import dataclasses
    import itertools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import butterfly as bf_lib
    from repro.core import wire_codec
    from repro.data import lm_batches
    from repro.models import model as M
    from repro.models import transformer as tfm
    from repro.training import (AdamWConfig, adamw_init, constant_schedule,
                                make_train_step)

    d_r, steps, rate_weight = 32, 120, 0.35

    def batches(skip, n):
        return list(itertools.islice(lm_batches(64, 32, 8, seed=5),
                                     skip, skip + n))

    def make(rw):
        cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                                  vocab_size=64)
        cfg = cfg.with_butterfly(layer=1, d_r=d_r, wire_bits=8,
                                 rate_weight=rw)
        built = M.build(cfg)
        step = jax.jit(make_train_step(
            built, AdamWConfig(lr=constant_schedule(3e-3))))
        return built, step

    def run(step, params, skip):
        opt = adamw_init(params)
        for raw in batches(skip, steps):
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt, _ = step(params, opt, batch)
        return params

    def boundary_codes(params, built, batch):
        x = M._embed_inputs(params, built, batch)
        x, _, _ = tfm.apply_stage(
            list(built.stages[0]), params["stages"][0], x, cfg=built.cfg,
            pctx=M.LOCAL, mode="train", stage_cache=None, pos=None,
            enc_out=None, shared_params=params.get("shared_attn"),
            use_kernel=False)
        codes, _ = bf_lib.reduce_unit(params["butterfly"], x)
        return np.asarray(codes).reshape(-1, d_r)

    def eval_loss(params, built):
        losses = []
        for raw in batches(steps, 32):
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            logits, _ = M.forward_train(params, built, batch)
            losses.append(float(M.lm_loss(logits, batch["targets"])))
        return float(np.mean(losses))

    built0, step0 = make(0.0)
    built_r, step_r = make(rate_weight)
    params, _ = M.init_model(jax.random.key(0), built0)
    prefix = run(step0, params, 0)
    base = run(step0, prefix, 0)         # epoch 2, rate-free
    ent = run(step_r, prefix, 0)         # epoch 2, rate-aware
    base_loss = eval_loss(base, built0)
    ent_loss = eval_loss(ent, built_r)

    counts = np.zeros((d_r, 256), np.int64)
    for raw in batches(steps - 8, 8):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        counts += wire_codec.channel_counts(
            boundary_codes(ent, built_r, batch), 8)
    prior = wire_codec.WirePrior.from_counts(counts, 8)
    nbytes, rows = 0, 0
    for raw in batches(steps, 8):
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        codes = boundary_codes(ent, built_r, batch)
        nbytes += len(wire_codec.encode(codes, prior)) + 4 * codes.shape[0]
        rows += codes.shape[0]
    ent_bpt = nbytes / rows
    int8_bpt = float(d_r + 4)            # codes + one f32 scale per row
    return {"d_r": d_r, "rate_weight": rate_weight,
            "train_steps": 2 * steps,
            "int8_bytes_per_token": round(int8_bpt, 2),
            "entropy_bytes_per_token": round(ent_bpt, 2),
            "entropy_bytes_reduction": round(int8_bpt / ent_bpt, 2),
            "eval_loss_base": round(base_loss, 4),
            "eval_loss_entropy": round(ent_loss, 4),
            "eval_loss_delta_pct": round(
                100.0 * (ent_loss - base_loss) / base_loss, 2)}


def bench_runtime():
    """Split-serving runtime: cloud-only (raw upload) vs the butterfly split
    under identical Poisson traffic, a streamed vs cache-handoff decode
    transport comparison on a long-prompt/multi-token workload (both runs on
    the SAME arrival trace via the shared builder), the adaptive
    controller's split trajectory under a cloud-load ramp, and a multi-cell
    topology scenario (heterogeneous fleets on per-cell radios vs the same
    fleet through one shared 3g wire, per-cell controllers diverging), and a
    resilience scenario (the same topology under a chaos fault schedule —
    availability, tail latency and migration/retry counts vs the calm run),
    and an entropy-wire scenario (trained-prior codec economics plus the
    four wire/transport configs on one long-prompt trace).
    Emits one JSON document (runtime/json row) with the full comparison."""
    import dataclasses

    from repro.configs import get_config
    from repro.core.profiler import JETSON_TX2
    from repro.runtime.simulator import (CellSpec, SimConfig, Simulation,
                                         WorkloadSpec, poisson_arrivals,
                                         ramp_load)

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), num_layers=4)
    base = SimConfig(cfg=cfg, network="3g", num_devices=4, num_requests=32,
                     arrival_rate=20.0, prompt_len=32, max_new_tokens=1,
                     d_r=16, numerics=False, seed=0)
    from repro.runtime.telemetry import SCHEMA_VERSION
    result = {"schema_version": SCHEMA_VERSION,
              "workload": {"arch": cfg.name, "layers": cfg.num_layers,
                           "devices": 4, "requests": 32, "prompt_len": 32,
                           "d_r": 16}, "networks": {}}
    t0 = time.perf_counter()
    for net in ("3g", "4g", "wifi"):
        row = {}
        for label, mode, wm in (("cloud_only", "cloud", "int8"),
                                ("split_raw", "split", "raw"),
                                ("split_reduced", "split", "reduced"),
                                ("split_int8", "split", "int8")):
            sc = dataclasses.replace(base, network=net, mode=mode,
                                     wire_mode=wm)
            s = Simulation(sc).run().summary()
            row[label] = {"latency_p50_ms": round(s["latency_p50_ms"], 3),
                          "latency_p95_ms": round(s["latency_p95_ms"], 3),
                          "latency_p99_ms": round(s["latency_p99_ms"], 3),
                          "mean_wire_kb": round(s["mean_wire_kb"], 3),
                          "mean_mobile_energy_mj":
                              round(s["mean_mobile_energy_mj"], 3)}
            if s["throughput_rps"] == s["throughput_rps"]:  # skip NaN
                row[label]["throughput_rps"] = round(s["throughput_rps"], 2)
        row["split_speedup_vs_cloud"] = round(
            row["cloud_only"]["latency_p50_ms"] /
            row["split_int8"]["latency_p50_ms"], 2)
        result["networks"][net] = row
        print(f"runtime/{net},0,split_p50="
              f"{row['split_int8']['latency_p50_ms']:.2f}ms "
              f"cloud_p50={row['cloud_only']['latency_p50_ms']:.2f}ms "
              f"speedup={row['split_speedup_vs_cloud']:.1f}x")
    # decode transports head to head: long prompt + multi-token generation
    # on 3g, identical arrival trace (the shared Poisson builder) — cache
    # handoff pays prompt-proportional KV bytes up front, streamed pays one
    # (1, d_r) row + one id RTT per token
    tr_prompt, tr_tokens = 128, 8
    arrivals = poisson_arrivals(num_devices=4, num_requests=24,
                                arrival_rate=20.0, prompt_len=tr_prompt,
                                seed=0)
    tr = {"workload": {"prompt_len": tr_prompt, "max_new_tokens": tr_tokens,
                       "network": "3g", "requests": 24}}
    for tp in ("cache_handoff", "streamed"):
        sc = dataclasses.replace(base, network="3g", mode="split",
                                 wire_mode="int8", transport=tp,
                                 num_requests=24, prompt_len=tr_prompt,
                                 max_new_tokens=tr_tokens, arrivals=arrivals)
        s = Simulation(sc).run().summary()
        tr[tp] = {"latency_p50_ms": round(s["latency_p50_ms"], 3),
                  "ttft_p50_ms": round(s["ttft_p50_ms"], 3),
                  "mean_uplink_kb": round(s["mean_wire_kb"], 3),
                  "mean_downlink_b": round(s["mean_downlink_b"], 3),
                  "mean_stream_rtt_ms": round(s["mean_stream_rtt_ms"], 4)}
    tr["streamed_uplink_reduction"] = round(
        tr["cache_handoff"]["mean_uplink_kb"] /
        tr["streamed"]["mean_uplink_kb"], 2)
    result["transports"] = tr
    print(f"runtime/transports,0,uplink handoff="
          f"{tr['cache_handoff']['mean_uplink_kb']:.2f}kB streamed="
          f"{tr['streamed']['mean_uplink_kb']:.2f}kB "
          f"({tr['streamed_uplink_reduction']:.1f}x less) p50 handoff="
          f"{tr['cache_handoff']['latency_p50_ms']:.2f}ms streamed="
          f"{tr['streamed']['latency_p50_ms']:.2f}ms")
    # adaptive split under a load ramp: cloud starts 10x the edge, external
    # tenants ramp to 97% — the controller must push the split deeper as the
    # derated cloud drops below edge speed (load > 0.9)
    sc = dataclasses.replace(
        base, mode="split", wire_mode="int8", num_requests=64,
        arrival_rate=40.0, adapt=True, control_interval_s=0.02,
        cloud=JETSON_TX2.scaled(10, "cloud_slice"),
        background_load=ramp_load(0.0, 0.25, 0.0, 0.97))
    tel = Simulation(sc).run()
    traj = [{"t": round(d["t"], 3), "cloud_load": round(d["cloud_load"], 3),
             "split": d["split"]} for d in tel.split_trajectory()]
    result["adaptive"] = {
        "cloud_over_edge": 10.0,
        "trajectory": traj,
        "split_at_low_load": traj[0]["split"],
        "split_at_high_load": traj[-1]["split"],
        "moved_deeper_past_0.9": traj[-1]["split"] > traj[0]["split"],
    }
    print(f"runtime/adaptive,0,split "
          f"{traj[0]['split']}->{traj[-1]['split']} as load crosses 0.9")
    # multi-cell topology: jetson-class gateways on a 3g backhaul + phones
    # on home wifi, one cloud at 95% background load.  Device class is the
    # split-depth lever (the fast edge absorbs the congested cloud's work),
    # the radio is the transport/contention lever — so the per-cell
    # controllers must diverge.  The baseline forces the SAME fleet through
    # ONE shared 3g wire (a single wire group), which couples the cells'
    # contention and erases the wifi cell's advantage.
    cells = (CellSpec(name="3g-jet", network="3g", num_devices=4,
                      device="jetson"),
             CellSpec(name="wifi-ph", network="wifi", num_devices=4,
                      device="phone"))
    shared = tuple(dataclasses.replace(c, network="3g", wire="up0")
                   for c in cells)
    topo_base = dataclasses.replace(
        base, num_requests=48, prompt_len=64, max_new_tokens=8,
        adapt=True, transport="auto", control_interval_s=0.02,
        background_load=lambda t: 0.95)
    topo = {"spec": "3g:4xjetson + wifi:4xphone @ cloud load 0.95",
            "cells": {}}
    sim = Simulation(dataclasses.replace(topo_base, topology=cells))
    tel = sim.run()
    per_cell = tel.cell_summary()
    for cell in sim.cells:
        last = [d for d in tel.decisions if d.cell == cell.name][-1]
        row = per_cell[cell.name]
        topo["cells"][cell.name] = {
            "latency_p50_ms": round(row["latency_p50_ms"], 3),
            "mean_uplink_wait_ms": round(row["mean_uplink_wait_ms"], 3),
            "mean_mobile_energy_mj": round(row["mean_mobile_energy_mj"], 3),
            "final_split": last.new_split,
            "final_transport": last.transport,
        }
    fair = tel.fairness()
    topo["fairness"] = {k: round(v, 4) for k, v in fair.items()}
    finals = [(c["final_split"], c["final_transport"])
              for c in topo["cells"].values()]
    topo["controllers_diverged"] = finals[0] != finals[1]
    assert topo["controllers_diverged"], \
        f"per-cell controllers failed to diverge: {topo['cells']}"
    assert topo["cells"]["3g-jet"]["final_split"] > \
        topo["cells"]["wifi-ph"]["final_split"], \
        "3g cell did not settle on the deeper split"
    shared_tel = Simulation(dataclasses.replace(
        topo_base, topology=shared)).run()
    topo["shared_3g_wire"] = {
        "latency_p50_ms": round(shared_tel.summary()["latency_p50_ms"], 3),
        "fairness_jain": round(shared_tel.fairness()["jain_index"], 4),
    }
    topo["isolated_vs_shared_p50_speedup"] = round(
        shared_tel.summary()["latency_p50_ms"] /
        tel.summary()["latency_p50_ms"], 2)
    result["topology"] = topo
    # resilience: the same heterogeneous topology under a chaos schedule
    # (device churn, a 3g->wifi handover, a wire blackout, a cloud outage
    # window, a mid-run join) vs the calm run above — what availability and
    # tail latency survive, and how much migration/retry work it took
    chaos_cfg = dataclasses.replace(
        topo_base, topology=cells,
        faults="handover@0.05:3g-jet>wifi,blackout@0.08:wifi-ph+0.03,"
               "outage@0.12+0.1,leave@0.15:1,join@0.2:3g-jet")
    chaos = Simulation(chaos_cfg).run().summary()
    calm = tel.summary()
    result["resilience"] = {
        "faults": chaos_cfg.faults,
        "availability_pct": round(chaos["availability_pct"], 2),
        "latency_p99_ms": round(chaos["latency_p99_ms"], 3),
        "baseline_p99_ms": round(calm["latency_p99_ms"], 3),
        "n_migrated": int(chaos["n_migrated"]),
        "n_retried": int(chaos["n_retried"]),
        "n_failed": int(chaos["n_failed"]),
        "n_edge_fallback": int(chaos["n_fallback"]),
    }
    print(f"runtime/resilience,0,"
          f"avail={chaos['availability_pct']:.1f}% "
          f"p99={chaos['latency_p99_ms']:.2f}ms "
          f"(calm {calm['latency_p99_ms']:.2f}ms) "
          f"migrated={result['resilience']['n_migrated']} "
          f"retried={result['resilience']['n_retried']} "
          f"failed={result['resilience']['n_failed']}")
    # decode pipelining: per-token microbatch rotation on the 2-pod mesh
    # (serving/pipeline.make_decode_pipeline).  The CPU simulator cannot
    # time real cross-pod overlap, so the tick cadence comes from the same
    # profiled roofline the planner trusts: serial = edge + wire + cloud in
    # sequence, pipelined = max of the three (>= 2 in-flight microbatches).
    from repro.core.profiler import GTX_1080TI
    from repro.core.wireless import NETWORKS
    from repro.runtime.split_exec import CostModel

    cost = CostModel(cfg, JETSON_TX2, GTX_1080TI)
    dp_split, dp_dr, dp_net = 1, 16, "4g"
    link_bps = NETWORKS[dp_net].uplink_mbps * 1e6
    serial_s = cost.serial_decode_tick_s(dp_split, dp_dr, wire_mode="int8",
                                         link_bps=link_bps)
    pipe_s = cost.pipelined_decode_tick_s(dp_split, dp_dr, wire_mode="int8",
                                          link_bps=link_bps)
    row8 = cost.stream_row_bytes("int8", dp_dr)
    row4 = cost.stream_row_bytes("int4", dp_dr)
    scale_b = row8 - dp_dr                   # f32 scales, same either way
    dp = {
        "workload": {"split": dp_split, "d_r": dp_dr, "network": dp_net,
                     "num_microbatches": 2},
        "serial_tick_us": round(serial_s * 1e6, 2),
        "pipelined_tick_us": round(pipe_s * 1e6, 2),
        "tokens_per_s_serial": round(1.0 / serial_s, 1),
        "tokens_per_s_pipelined": round(1.0 / pipe_s, 1),
        "pipeline_speedup": round(serial_s / pipe_s, 3),
        "wire_row_bytes_int8": row8,
        "wire_row_bytes_int4": row4,
        "int4_code_reduction": round((row8 - scale_b) / (row4 - scale_b), 2),
        "int4_uplink_reduction": round(row8 / row4, 3),
    }
    assert dp["pipeline_speedup"] >= 1.5, dp
    assert dp["int4_code_reduction"] == 2.0, dp
    result["decode_pipeline"] = dp
    print(f"runtime/decode_pipeline,0,"
          f"serial={dp['serial_tick_us']:.1f}us "
          f"pipelined={dp['pipelined_tick_us']:.1f}us "
          f"speedup={dp['pipeline_speedup']:.2f}x "
          f"int4_row={row4:.0f}B vs int8_row={row8:.0f}B "
          f"({dp['int4_uplink_reduction']:.2f}x less)")
    # gateway: a 10^5-request Pareto-gap flash crowd on a cloud-bound
    # 2-pod topology (negligible inter-pod wire, 95% background tenants,
    # so the shared slot pool is the contended resource).  SLO-classed
    # shedding on vs off, same arrival trace: without admission control
    # the queue melts and interactive p99 is the whole backlog; with
    # "priority,shed" the batch class absorbs the shed and interactive
    # requests keep their SLO through the spike.
    gw_pods = (CellSpec(name="pod-jet", network="inter_pod", num_devices=4,
                        device="jetson"),
               CellSpec(name="pod-ph", network="inter_pod", num_devices=4,
                        device="phone"))
    gw_wl = WorkloadSpec(kind="flash", rate=6.0, n=100_000, alpha=1.5,
                         interactive=0.25, at=5.0, dur=30.0, burst=20.0)
    gw_policy = "priority,shed,slo=150/1000,reserve=1"
    gw_base = dataclasses.replace(
        base, topology=gw_pods, num_requests=0, max_new_tokens=16,
        max_concurrent=4, workload=gw_wl,
        background_load=lambda t: 0.95)
    gw_t0 = time.perf_counter()
    gw_off = Simulation(gw_base).run()
    gw_on = Simulation(dataclasses.replace(
        gw_base, gateway=gw_policy)).run()
    off_cls, on_cls = gw_off.class_summary(), gw_on.class_summary()
    on_sum = gw_on.summary()
    gw_speedup = round(off_cls["interactive"]["latency_p99_ms"] /
                       on_cls["interactive"]["latency_p99_ms"], 1)
    gw = {
        "workload": {"kind": gw_wl.kind, "rate": gw_wl.rate, "n": gw_wl.n,
                     "alpha": gw_wl.alpha, "interactive": gw_wl.interactive,
                     "at": gw_wl.at, "dur": gw_wl.dur, "burst": gw_wl.burst,
                     "policy": gw_policy},
        "interactive_p99_off_ms": round(
            off_cls["interactive"]["latency_p99_ms"], 3),
        "interactive_p99_on_ms": round(
            on_cls["interactive"]["latency_p99_ms"], 3),
        "batch_p99_off_ms": round(off_cls["batch"]["latency_p99_ms"], 3),
        "batch_p99_on_ms": round(on_cls["batch"]["latency_p99_ms"], 3),
        "shed_interactive_p99_speedup": gw_speedup,
        "n_shed": int(on_sum["n_shed"]),
        "n_shed_interactive": int(on_cls["interactive"]["n_shed"]),
        "wall_s_100k_pair": round(time.perf_counter() - gw_t0, 1),
    }
    # acceptance floor (ISSUE 9): shedding buys >= 3x interactive p99
    assert gw["shed_interactive_p99_speedup"] >= 3.0, gw
    assert on_sum["n_done"] + on_sum["n_failed"] + on_sum["n_shed"] == \
        gw_wl.n, on_sum
    assert gw["n_shed_interactive"] == 0, \
        f"shed fell on the protected class: {gw}"
    result["gateway"] = gw
    print(f"runtime/gateway,0,"
          f"int_p99_on={gw['interactive_p99_on_ms']:.1f}ms "
          f"int_p99_off={gw['interactive_p99_off_ms']:.1f}ms "
          f"speedup={gw_speedup:.0f}x shed={gw['n_shed']} "
          f"(interactive shed {gw['n_shed_interactive']}) "
          f"100k_pair={gw['wall_s_100k_pair']:.0f}s")
    us = (time.perf_counter() - t0) * 1e6
    print(f"runtime/topology,{us/15:.0f},"
          f"3g-jet=(s{topo['cells']['3g-jet']['final_split']},"
          f"{topo['cells']['3g-jet']['final_transport']}) "
          f"wifi-ph=(s{topo['cells']['wifi-ph']['final_split']},"
          f"{topo['cells']['wifi-ph']['final_transport']}) "
          f"jain={topo['fairness']['jain_index']} "
          f"shared_3g_p50={topo['shared_3g_wire']['latency_p50_ms']:.2f}ms "
          f"({topo['isolated_vs_shared_p50_speedup']}x slower than "
          f"per-cell radios)")
    # wire: the learned entropy-coded wire.  Part A prices the codec with
    # a trained per-channel prior (real encoder, held-out codes); Part B
    # replays one long-prompt 3g trace through the four wire/transport
    # configurations.  The trace runs a deeper model against a slow cloud
    # (12 layers, cloud at 0.5x edge) so prefill compute is substantial —
    # the regime where the progressive transport's upload/prefill overlap
    # pays; on shallow/fast-cloud workloads the 4-byte refinement header
    # is all you see.
    wt0 = time.perf_counter()
    codec = _wire_codec_report()
    wire_cfg = dataclasses.replace(cfg, num_layers=12)
    wire_arrivals = poisson_arrivals(num_devices=4, num_requests=24,
                                     arrival_rate=4.0, prompt_len=128,
                                     vocab_size=cfg.vocab_size, seed=0)
    wire_base = SimConfig(
        cfg=wire_cfg, mode="split", network="3g", num_devices=4,
        num_requests=24, arrival_rate=4.0, prompt_len=128, max_new_tokens=8,
        d_r=16, numerics=False, seed=0, edge=JETSON_TX2,
        cloud=JETSON_TX2.scaled(0.5, "cloud_slice"), arrivals=wire_arrivals)
    wire_modes = {}
    for label, wm, tp in (("int8", "int8", "streamed"),
                          ("int4", "int4", "streamed"),
                          ("entropy", "entropy", "streamed"),
                          ("entropy_progressive", "entropy", "progressive")):
        s = Simulation(dataclasses.replace(
            wire_base, wire_mode=wm, transport=tp)).run().summary()
        row = {"mean_wire_kb": round(s["mean_wire_kb"], 3),
               "ttft_p50_ms": round(s["ttft_p50_ms"], 3),
               "latency_p50_ms": round(s["latency_p50_ms"], 3)}
        if "compression_ratio" in s:
            row["compression_ratio"] = round(s["compression_ratio"], 3)
        wire_modes[label] = row
    prog_ttft_speedup = round(wire_modes["entropy"]["ttft_p50_ms"] /
                              wire_modes["entropy_progressive"]["ttft_p50_ms"],
                              3)
    wire = {"codec": codec,
            "workload": {"network": "3g", "prompt_len": 128,
                         "max_new_tokens": 8, "layers": 12, "cloud_x": 0.5,
                         "requests": 24},
            "modes": wire_modes,
            "progressive_ttft_p50_speedup": prog_ttft_speedup,
            "progressive_latency_p50_speedup": round(
                wire_modes["entropy"]["latency_p50_ms"] /
                wire_modes["entropy_progressive"]["latency_p50_ms"], 3)}
    # acceptance floors (ISSUE 10): >=2x coded bytes at <2% eval-loss
    # delta, and the overlap must actually buy first-token latency
    assert codec["entropy_bytes_reduction"] >= 2.0, codec
    assert codec["eval_loss_delta_pct"] < 2.0, codec
    assert prog_ttft_speedup > 1.0, wire
    result["wire"] = wire
    print(f"runtime/wire,{(time.perf_counter() - wt0) * 1e6 / 6:.0f},"
          f"codec={codec['entropy_bytes_per_token']:.1f}B/tok vs "
          f"int8={codec['int8_bytes_per_token']:.0f}B/tok "
          f"({codec['entropy_bytes_reduction']:.2f}x) "
          f"dloss={codec['eval_loss_delta_pct']:+.2f}% "
          f"prog_ttft={prog_ttft_speedup:.3f}x "
          f"prog_p50={wire['progressive_latency_p50_speedup']:.3f}x")
    print(f"runtime/json,0,{json.dumps(result, sort_keys=True)}")
    _append_runtime_artifact(result)


def bench_transport():
    """Decode-transport smoke (CI): tiny 2-layer config with real numerics,
    both transports on the identical arrival trace — greedy token streams
    must match each other and the hosted single-mesh reference exactly, the
    downlink must carry the sampled ids, and streamed uplink bytes must
    undercut the cache handoff.  Raises on any violation."""
    import dataclasses

    from repro.configs import get_config
    from repro.runtime.simulator import SimConfig, Simulation, poisson_arrivals

    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(), num_layers=2)
    arrivals = poisson_arrivals(num_devices=2, num_requests=4,
                                arrival_rate=20.0, prompt_len=16,
                                vocab_size=cfg.vocab_size, seed=0)
    t0 = time.perf_counter()
    streams, sims, summaries = {}, {}, {}
    for tp in ("cache_handoff", "streamed"):
        sc = SimConfig(cfg=cfg, mode="split", wire_mode="int8", network="3g",
                       num_devices=2, num_requests=4, arrival_rate=20.0,
                       prompt_len=16, max_new_tokens=3, d_r=16, numerics=True,
                       max_concurrent=2, transport=tp, seed=0,
                       arrivals=arrivals)
        sim = Simulation(sc)
        tel = sim.run()
        sims[tp], summaries[tp] = sim, tel.summary()
        streams[tp] = {r.uid: list(r.engine_req.generated)
                       for r in sim.requests}
        assert summaries[tp]["total_downlink_kb"] > 0, \
            f"{tp}: downlink carried no sampled ids"
    assert streams["cache_handoff"] == streams["streamed"], \
        "transport parity violated: greedy streams differ"
    runner = sims["streamed"].bank.runner(1)
    eng = runner.make_engine(max_batch=2, max_len=24, seed=0)
    for req in sims["streamed"].requests:
        ref = eng.submit(req.tokens, max_new_tokens=3)
        eng.run()
        assert list(ref.generated) == streams["streamed"][req.uid], \
            f"uid {req.uid}: streamed != single-mesh reference"
    up_h = summaries["cache_handoff"]["mean_wire_kb"]
    up_s = summaries["streamed"]["mean_wire_kb"]
    assert up_s < up_h, "streamed did not reduce uplink bytes"
    us = (time.perf_counter() - t0) * 1e6
    print(f"transport/parity,{us/2:.0f},greedy_streams_match=3way "
          f"uplink handoff={up_h:.2f}kB streamed={up_s:.2f}kB")
    print(f"transport/downlink,0,"
          f"handoff={summaries['cache_handoff']['total_downlink_kb']*1e3:.0f}B "
          f"streamed={summaries['streamed']['total_downlink_kb']*1e3:.0f}B "
          f"rtt={summaries['streamed']['mean_stream_rtt_ms']:.2f}ms")


def _append_runtime_artifact(result: dict) -> None:
    """Append this run's runtime JSON to experiments/BENCH_runtime.json via
    the one writer in experiments/aggregate.py (which also renders it)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "experiments"))
    from aggregate import append_runs
    append_runs([result])


BENCHES = {
    "fig7": bench_fig7,
    "bank": bench_bank,
    "runtime": bench_runtime,
    "transport": bench_transport,
    "wirebits": bench_wirebits,
    "table4": bench_table4,
    "table5": bench_table5,
    "sec3d": bench_sec3d,
    "wire": bench_wire,
    "roofline": bench_roofline,
    "micro": bench_micro,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == '__main__':
    main()
