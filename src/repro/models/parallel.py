"""ParallelContext: how model code sees the mesh.

Model code never imports the launcher; it receives a ParallelContext that is
either ``LOCAL`` (single device, tests/benches) or built from the production
mesh (dry-run / train / serve).  MoE uses it for explicit shard_map expert
parallelism; everything else uses GSPMD propagation from the param specs.

Two execution regimes share the dataclass (DESIGN.md section 11):

* **automatic** (``manual=False``): the context wraps the full mesh and layer
  code relies on GSPMD propagation (or opens its own shard_map, as MoE does).
* **manual** (``manual=True``): the code is *already inside* a shard_map body
  — every mesh axis is manual, params arrive as per-rank shards, and layer
  code must issue explicit collectives.  The split pipeline runs its stages
  this way on a 2-D ``(pod, model)`` mesh: attention heads / d_ff / experts
  shard over ``model`` (Megatron column->row within a pod), each partial
  output is ``psum``'d over ``model`` via :func:`model_psum`, and only the
  fused-quantized butterfly codes ever cross the ``pod`` axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[jax.sharding.Mesh]
    data_axes: Tuple[str, ...] = ("data",)     # ("pod", "data") when multi-pod
    model_axis: str = "model"
    pod_axis: str = "pod"
    # True when the owning computation already runs inside a shard_map body:
    # params are per-rank shards and layer code must psum partial outputs
    # over ``model_axis`` itself (see transformer.apply_layer / moe.apply_moe)
    manual: bool = False

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def mp_size(self) -> int:
        if self.mesh is None or self.model_axis not in self.mesh.shape:
            return 1
        return int(self.mesh.shape[self.model_axis])

    @property
    def tensor_parallel(self) -> bool:
        """True when layer params are model-axis shards that demand explicit
        partial-output reduction (the manual regime with a real model axis)."""
        return self.manual and self.mp_size > 1

    def batch_spec_axes(self):
        """Axes tuple for sharding a batch dim (None when local)."""
        if self.mesh is None:
            return None
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


LOCAL = ParallelContext(mesh=None)


def make_context(mesh: Optional[jax.sharding.Mesh]) -> ParallelContext:
    if mesh is None:
        return LOCAL
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    return ParallelContext(mesh=mesh, data_axes=data_axes, model_axis="model")


def manual_context(mesh: Optional[jax.sharding.Mesh], *,
                   model_axis: str = "model") -> ParallelContext:
    """Context for layer code running *inside* a shard_map body over ``mesh``.

    ``data_axes`` is empty on purpose: inside the body every rank sees its
    local batch shard already, so nothing may re-shard the batch dim.  With
    ``mesh=None`` (or a mesh without ``model_axis``) this degrades to a
    LOCAL-equivalent context, which keeps single-degree callers on the exact
    replicated code path."""
    if mesh is None:
        return LOCAL
    return ParallelContext(mesh=mesh, data_axes=(), model_axis=model_axis,
                           manual=True)


def model_psum(x, pctx: ParallelContext):
    """Reduce a model-axis-partial activation; identity outside the manual
    tensor-parallel regime so replicated callers pay nothing."""
    if pctx.tensor_parallel:
        return jax.lax.psum(x, pctx.model_axis)
    return x
