"""The paper's own architecture: ResNet-50 (16 residual blocks) for the
faithful reproduction of Fig. 4/5/7 and Tables IV/V. [He et al. 2015; paper 3]

These are conv configs, handled by ``models/resnet.py`` rather than the
transformer stack; registered here so ``--arch resnet50`` works everywhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs.base import ButterflyConfig, register


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet50"
    arch_type: str = "resnet"
    # stage spec: (blocks, out_channels) per stage; ResNet-50 = 3,4,6,3
    stages: tuple = ((3, 256), (4, 512), (6, 1024), (3, 2048))
    stem_channels: int = 64
    num_classes: int = 100           # miniImageNet: 100 classes
    image_size: int = 224
    butterfly: Optional[ButterflyConfig] = None   # layer == residual-block index (1-based "after RB j")
    dtype: str = "float32"
    source: str = "arXiv:1512.03385; paper Figs. 4-6"

    @property
    def num_blocks(self) -> int:
        return sum(b for b, _ in self.stages)     # 16 for ResNet-50

    def block_channels(self) -> list[int]:
        """Output channel size of each residual block (paper's C_i)."""
        out = []
        for blocks, ch in self.stages:
            out += [ch] * blocks
        return out

    def block_spatial(self) -> list[int]:
        """Output spatial size (square) of each residual block for 224 input."""
        out, size = [], self.image_size // 4       # stem: conv s2 + pool s2 -> 56
        for si, (blocks, _) in enumerate(self.stages):
            if si > 0:
                size //= 2                          # first block of stage downsamples
            out += [size] * blocks
        return out

    def feature_bytes(self, block: int, bits: int = 8, channels: Optional[int] = None) -> int:
        """Wire bytes if offloading after residual block ``block`` (1-based)."""
        ch = channels if channels is not None else self.block_channels()[block - 1]
        sp = self.block_spatial()[block - 1]
        return (sp * sp * ch * bits + 7) // 8      # ceil: sub-byte wires pack

    def with_butterfly(self, block: int, d_r: int, wire_bits: int = 8) -> "ResNetConfig":
        return replace(self, butterfly=ButterflyConfig(layer=block, d_r=d_r, wire_bits=wire_bits))

    def reduced(self) -> "ResNetConfig":
        return replace(
            self, name=self.name + "-reduced",
            stages=((1, 32), (1, 64)), stem_channels=16,
            num_classes=10, image_size=32,
            butterfly=ButterflyConfig(layer=1, d_r=4) if self.butterfly else None,
        )


@register("resnet50")
def resnet50() -> ResNetConfig:
    return ResNetConfig()


# Minimal D_r per split reported by the paper (Fig. 7): RB1-3 -> 1, RB4-7 -> 2,
# RB8-13 -> 5, RB14-16 -> 10, for <2% accuracy loss on miniImageNet.
PAPER_MIN_DR = {**{rb: 1 for rb in (1, 2, 3)},
                **{rb: 2 for rb in (4, 5, 6, 7)},
                **{rb: 5 for rb in range(8, 14)},
                **{rb: 10 for rb in (14, 15, 16)}}
