from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    constant_schedule,
    cosine_schedule,
)
from repro.training.train_loop import (
    init_train_state,
    make_eval_step,
    make_loss_fn,
    make_train_step,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "constant_schedule",
    "cosine_schedule", "init_train_state", "make_eval_step", "make_loss_fn",
    "make_train_step",
]
