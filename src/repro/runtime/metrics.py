"""Time-series metrics + wall-clock jit profiling for the split runtime.

Three layers:

* :class:`MetricsRegistry` — named counters / gauges / histograms.
  ``Telemetry.counters`` is now a :class:`CountersView` over a registry, so
  every existing ``counters["x"] += 1`` call site keeps working while the
  same numbers become scrapeable alongside gauges and histograms.
* :class:`MetricsSampler` — a fixed-interval sampler scheduled on the
  :class:`~repro.runtime.clock.EventLoop` (virtual time): each tick polls a
  dict of named sources (queue depths, per-direction wire backlog and
  windowed goodput, cloud batch size / occupancy, per-cell in-flight
  counts) into one row; rows export as JSONL (``--metrics-out``).
  Sampling is *passive*: sources only read simulator state, so a sampled
  run's telemetry is identical to an unsampled one.

The fault layer (:mod:`repro.runtime.faults`) reports through the same
registry: ``fault_*`` counters (injections, retries, migrations, drops,
fallbacks) and the ``fault_backoff_s`` histogram of retry backoff delays.
* :class:`JitProfiler` — **wall-clock** compile-vs-execute attribution per
  jit cache entry (first call = compile + execute, later calls = steady
  state) for ``SplitModelBank`` / ``ServingEngine`` hot paths.  Wall time
  is host-dependent and therefore *never* enters virtual-clock traces or
  default telemetry: profiling is opt-in (``SimConfig.profile_jit``) and
  surfaces as a separate ``jit_profile`` section in the telemetry JSON —
  making "the sim says X ms but wall time is dominated by recompiles"
  visible.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, MutableMapping, Optional

METRICS_FORMAT = "runtime-metrics-v1"


# ---------------------------------------------------------------------------
# registry: counters / gauges / histograms
# ---------------------------------------------------------------------------


class Counter:
    """Cumulative value.  ``set`` exists for migration call sites that
    assign totals directly (e.g. ``counters["x"] = n``)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def set(self, v: float) -> None:
        self.value = float(v)


class Gauge:
    """Point-in-time value (last write wins)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact distribution (runs are bounded, so observations are kept and
    percentiles are deterministic — no bucket-boundary artifacts)."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def summary(self) -> Dict[str, float]:
        from repro.runtime.telemetry import percentile
        xs = self.values
        return {"count": len(xs), "sum": sum(xs),
                "mean": sum(xs) / len(xs) if xs else float("nan"),
                "p50": percentile(xs, 50), "p95": percentile(xs, 95),
                "max": max(xs) if xs else float("nan")}


class MetricsRegistry:
    """Get-or-create named instruments; one registry per simulation."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter()
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge()
        return self._gauges[name]

    def histogram(self, name: str) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram()
        return self._histograms[name]

    @property
    def counters(self) -> "CountersView":
        return CountersView(self)

    def counter_names(self) -> List[str]:
        return list(self._counters)

    def to_dict(self) -> Dict[str, dict]:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self._histograms.items()},
        }


class CountersView(MutableMapping):
    """``defaultdict(float)``-compatible dict view over a registry's
    counters — the back-compat face of ``Telemetry.counters``: reads
    auto-create at 0.0, ``+=`` and plain assignment both work, and
    ``dict(view)`` snapshots the values."""

    __slots__ = ("_registry",)

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry

    def __getitem__(self, name: str) -> float:
        return self._registry.counter(name).value

    def __setitem__(self, name: str, value: float) -> None:
        self._registry.counter(name).set(value)

    def __delitem__(self, name: str) -> None:
        del self._registry._counters[name]

    def __iter__(self):
        return iter(self._registry.counter_names())

    def __len__(self) -> int:
        return len(self._registry._counters)

    def __repr__(self) -> str:
        return f"CountersView({dict(self)!r})"


# ---------------------------------------------------------------------------
# fixed-interval sampler on the virtual clock
# ---------------------------------------------------------------------------


class MetricsSampler:
    """Snapshot named sources every ``interval_s`` of *virtual* time.

    ``sources`` maps a metric name to a ``f(now) -> float`` reader; each
    tick evaluates every source (in insertion order) into one row and
    mirrors the values into the registry's gauges.  The sampler arms on
    :meth:`start` (sampling t=0 immediately) and disarms on :meth:`stop`
    — the simulation stops it when the last request completes, so the
    event loop drains."""

    def __init__(self, loop, registry: MetricsRegistry, *,
                 interval_s: float = 0.01,
                 sources: Optional[Dict[str, Callable[[float], float]]]
                 = None):
        assert interval_s > 0, interval_s
        self.loop = loop
        self.registry = registry
        self.interval_s = interval_s
        self.sources: Dict[str, Callable[[float], float]] = dict(sources
                                                                 or {})
        self.rows: List[dict] = []
        self._cancel: Optional[Callable[[], None]] = None

    def add_source(self, name: str, fn: Callable[[float], float]) -> None:
        self.sources[name] = fn

    def start(self) -> None:
        assert self._cancel is None, "sampler already running"
        self._cancel = self.loop.schedule_every(
            self.interval_s, self._tick, first_delay=0.0)

    def stop(self) -> None:
        if self._cancel is not None:
            self._cancel()
            self._cancel = None

    def _tick(self) -> None:
        now = self.loop.now
        row = {"t": now}
        for name, fn in self.sources.items():
            v = float(fn(now))
            row[name] = v
            self.registry.gauge(name).set(v)
        self.rows.append(row)

    # ---------------------------------------------------------------- export
    def to_jsonl(self) -> str:
        header = {"format": METRICS_FORMAT, "interval_s": self.interval_s,
                  "n": len(self.rows), "sources": list(self.sources)}
        lines = [json.dumps(header, sort_keys=True)]
        lines += [json.dumps(row, sort_keys=True) for row in self.rows]
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


def read_metrics_jsonl(path: str) -> List[dict]:
    """Rebuild sampler rows from a ``--metrics-out`` file (header
    validated)."""
    with open(path) as f:
        header = json.loads(f.readline())
        assert header.get("format") == METRICS_FORMAT, \
            f"{path}: not a metrics timeline (header {header!r})"
        rows = [json.loads(line) for line in f if line.strip()]
    assert len(rows) == header["n"], \
        f"{path}: truncated ({len(rows)} of {header['n']} rows)"
    return rows


# ---------------------------------------------------------------------------
# wall-clock jit profiling (opt-in; never enters virtual-clock artifacts)
# ---------------------------------------------------------------------------


class JitProfiler:
    """Per-jit-cache-entry wall-clock attribution.

    A key is the bank's compile-cache tuple ``(kind, split, mp, B, S)`` (or
    an engine's ``("engine_step", split, mp)``): the first timed call of a
    key is the compile+execute path, every later call is steady state.
    ``timed`` blocks on the result (``jax.block_until_ready``) so wall
    times are honest — which is exactly why profiling is opt-in."""

    def __init__(self):
        self.entries: Dict[tuple, dict] = {}

    def timed(self, key: tuple, fn, *args):
        import jax
        t0 = time.perf_counter()
        out = fn(*args)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        e = self.entries.get(key)
        if e is None:
            self.entries[key] = {"first_call_s": dt, "calls": 1,
                                 "steady_s": 0.0}
        else:
            e["calls"] += 1
            e["steady_s"] += dt
        return out

    @property
    def first_calls(self) -> int:
        return len(self.entries)

    @property
    def steady_calls(self) -> int:
        return sum(e["calls"] - 1 for e in self.entries.values())

    @property
    def compile_wall_s(self) -> float:
        """Total first-call wall time (compile + one execute per entry)."""
        return sum(e["first_call_s"] for e in self.entries.values())

    @property
    def steady_wall_s(self) -> float:
        return sum(e["steady_s"] for e in self.entries.values())

    def summary(self) -> Dict[str, dict]:
        """JSON-ready per-entry attribution, keyed ``kind/split/mp/B/S``."""
        out = {}
        for key, e in sorted(self.entries.items(), key=lambda kv: str(kv[0])):
            steady = e["calls"] - 1
            out["/".join(str(k) for k in key)] = {
                "calls": e["calls"],
                "first_call_ms": round(e["first_call_s"] * 1e3, 3),
                "steady_calls": steady,
                "steady_mean_ms": round(e["steady_s"] / steady * 1e3, 3)
                if steady else None,
                "steady_total_ms": round(e["steady_s"] * 1e3, 3),
            }
        return out

    def headline(self) -> Dict[str, float]:
        """The one-line takeaway: how much wall time went to first calls
        (recompiles) vs steady-state execution."""
        total = self.compile_wall_s + self.steady_wall_s
        return {
            "entries": self.first_calls,
            "calls": self.first_calls + self.steady_calls,
            "compile_wall_ms": round(self.compile_wall_s * 1e3, 3),
            "steady_wall_ms": round(self.steady_wall_s * 1e3, 3),
            "compile_fraction": round(self.compile_wall_s / total, 4)
            if total > 0 else float("nan"),
        }
