import os
import sys

# src layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: never set xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (the 512-device mesh belongs to launch/dryrun.py
# only, and the pipeline test spawns a subprocess with its own flags).

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_report_header(config):
    return f"jax {jax.__version__}, devices={jax.device_count()}"
