"""The paper's deployment on the pod mesh: edge pod computes the prefix +
reduction unit, ONLY int8 codes + scales cross the pod boundary
(collective-permute), cloud pod restores and finishes, logits return.

Run:  PYTHONPATH=src python examples/split_serving.py
(sets 2 host devices before jax import — do not import jax before this)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving.pipeline import make_split_pipeline, wire_stats


def main():
    cfg = get_config("gemma3-12b").reduced().with_butterfly(layer=1, d_r=16)
    built = M.build(cfg)
    params, _ = M.init_model(jax.random.key(0), built)

    mesh = jax.make_mesh((2, 1), ("pod", "data"))
    Mmb, mb, S = 4, 2, 32
    toks = jax.random.randint(jax.random.key(1), (Mmb * mb, S), 0,
                              cfg.vocab_size)

    pipe = jax.jit(make_split_pipeline(built, mesh, Mmb, S, mb))
    logits = pipe(params, toks)

    stats = wire_stats(cfg, mb, S)
    print(f"arch {cfg.name}: butterfly after layer {cfg.butterfly.layer}, "
          f"d_model {cfg.d_model} -> d_r {cfg.butterfly.d_r}")
    print(f"pod-boundary bytes/microbatch: wire {stats['wire_bytes']:,} vs "
          f"raw {stats['raw_boundary_bytes']:,}  "
          f"({stats['compression']:.1f}x compression)")

    ref, _ = M.forward_train(params, built, {"tokens": toks})
    err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
    print(f"pipeline vs in-graph max |err|: {err:.2e}")

    hlo = jax.jit(pipe).lower(params, toks).compile().as_text()
    n_int8_perm = sum(1 for l in hlo.splitlines()
                      if "collective-permute" in l and "s8[" in l)
    print(f"int8 collective-permutes in compiled HLO: {n_int8_perm}")


if __name__ == "__main__":
    main()
