"""Pluggable decode transports: how a multi-token split request moves its
decode phase between the edge and the cloud.

``cache_handoff``  (prefill/decode disaggregation, the runtime's historical
behavior, extracted here): the edge ships its stage-0 KV cache up with the
prefill codes, the cloud decodes whole tokens locally in the batch engine,
and the sampled ids come back in one downlink shipment at completion.
Uplink cost grows with prompt length (the cache), decode steps are cheap
(no wire on the token path).

``streamed``  (decode over the wire, DESIGN.md section 8.6): the edge keeps
a decode cache for layers [0, j) and, per generated token, embeds the
token, runs its half, and sends ONE fused-quantized ``(1, d_r)`` row
through the butterfly; the cloud applies restore + layers [j, N) against
its own cache and returns the sampled id over the downlink.  Uplink cost is
flat in prompt length; every token pays one RTT (row up + cloud turn + id
down).

JointDNN's observation that generation workloads want a different
partition/transport than one-shot inference is exactly this trade: long
prompt + long generation favors ``streamed`` (the handoff cache dominates),
short prompt + fat RTT favors ``cache_handoff``.  The controller can pick
per request (``transport="auto"``) via the same online selection phase that
picks the split (core/planner.select_split_online).

``progressive``  (entropy-coded upload/prefill overlap, DESIGN.md section
18): streamed decode plus a two-chunk prefill upload — the high-order
coarse bitplanes (and scales) ship first, the refinement planes queue
right behind on the same FIFO uplink, and the cloud starts its prefill as
soon as the coarse chunk lands, overlapping the accelerator with the
upload tail.  The first sampled token is gated on the refinement landing,
so decode numerics always see the FULL codes — bitwise parity with
``streamed`` — while TTFT stops paying for the serialized tail.

The transport objects are stateless singletons: they own the per-request
choreography (what crosses which wire when, who keeps which cache) while
the actors keep the machinery (serial frontiers, slot pools, batched
service turns).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import wire_codec
from repro.core.costs import TOKEN_BYTES

# deployment-default rANS prior shared by every entropy-wire request of a
# given width (the same default the codec benchmarks train against); cached
# because WirePrior.default builds a fresh frequency table per call
_DEFAULT_PRIORS: dict = {}


def _default_prior(d_r: int, bits: int = 8) -> wire_codec.WirePrior:
    key = (d_r, bits)
    if key not in _DEFAULT_PRIORS:
        _DEFAULT_PRIORS[key] = wire_codec.WirePrior.default(d_r, bits)
    return _DEFAULT_PRIORS[key]


def _entropy_payload_adjust(device, req) -> float:
    """Entropy-wire byte accounting (schema v5): swap the planner's
    nominal-rate prediction for the ACTUAL rANS size of this request's
    codes when they exist (numerics mode), stamping the trace's
    ``coded_bytes``/``nominal_bytes`` fields either way.  Returns the
    delta to add to the predicted uplink total.  Timing-only runs (no
    bank) keep the deterministic nominal prediction — delta 0.0 — so
    record->replay stays byte-identical in both modes (the encoder is a
    pure function of the codes)."""
    from repro.core.planner import wire_mode_bytes

    t = req.trace
    predicted = wire_mode_bytes(device.cost.cfg, t.prompt_len, device.d_r,
                                "entropy")
    raw_int8 = wire_mode_bytes(device.cost.cfg, t.prompt_len, device.d_r,
                               "int8")
    coded = predicted
    delta = 0.0
    if req.payload is not None and req.payload[0] is not None:
        codes = np.asarray(req.payload[0][0])          # (S, d_r) int8
        actual = wire_codec.coded_nbytes(
            codes, _default_prior(device.d_r)) + t.prompt_len * 4
        # same escape hatch as the planner: the edge ships raw int8 codes
        # when coding would expand the payload
        actual = float(min(actual, raw_int8))
        delta = actual - predicted
        coded = actual
    t.coded_bytes += coded
    t.nominal_bytes += raw_int8
    return delta


class DecodeTransport:
    """Per-request decode choreography; subclasses are stateless."""

    name: str = "?"
    streams_tokens: bool = False

    def prefill_uplink_bytes(self, device, req) -> float:
        t = req.trace
        total = device.cost.payload_bytes(
            device.mode, device.wire_mode, t.prompt_len, device.d_r,
            t.split, req.max_new_tokens, transport=self.name)
        if device.wire_mode == "entropy" and device.mode == "split":
            total += _entropy_payload_adjust(device, req)
        return total

    def after_edge_prefill(self, device, req) -> None:
        """Hook between the edge prefill numerics and the uplink."""

    def start_cloud_decode(self, server, req) -> None:
        raise NotImplementedError


class CacheHandoffTransport(DecodeTransport):
    """Ship the stage-0 cache up; decode entirely cloud-side.  The sampled
    ids come down in one shipment at completion, so the mobile's first
    token arrives with the last — TTFT is stamped at delivery
    (CloudServer._deliver), the same observation point the streamed
    transport uses."""

    name = "cache_handoff"

    def start_cloud_decode(self, server, req) -> None:
        t = req.trace
        eng = server._engine(t.split)
        if eng is not None:
            if server.mode == "split":
                logits_row, cache1, cache0 = server._cloud_numerics(req)
                req.engine_req = eng.submit_prefilled(
                    t.prompt_len, [cache0, cache1], logits_row,
                    max_new_tokens=req.max_new_tokens)
            else:
                req.engine_req = eng.submit(
                    req.tokens, max_new_tokens=req.max_new_tokens)
            req.payload = None
            if req.engine_req.done:
                server._complete(req)
        else:
            server._virtual_left[t.uid] = req.max_new_tokens - 1
            if server._virtual_left[t.uid] <= 0:
                server._complete(req)


class StreamedTransport(DecodeTransport):
    """Keep the stage-0 cache on the edge; stream one row per token."""

    name = "streamed"
    streams_tokens = True

    # -- edge side ----------------------------------------------------------
    def after_edge_prefill(self, device, req) -> None:
        """The edge retains its stage-0 cache (padded to decode capacity)
        instead of shipping it; only codes + scales cross the uplink."""
        t = req.trace
        req.edge_pos = t.prompt_len
        if device.bank is not None and req.payload is not None:
            codes, scales, cache0 = req.payload
            runner = device.runner(t.split)
            req.edge_cache = runner.pad_decode_cache(
                cache0, 0, device.server.max_len)
            req.payload = (codes, scales, None)

    def token_at_device(self, device, req, tok, seq=None) -> None:
        """A sampled id reached the mobile: either the response is complete,
        or the edge runs its per-token half and streams the next row.
        ``seq`` (1-based, set by the fault-aware send path) makes delivery
        idempotent: a retried token the original beat is dropped."""
        t = req.trace
        now = device.loop.now
        if req.finished:
            return
        if seq is not None and seq <= req.produced:
            device.telemetry.counters["fault_duplicate_tokens"] += 1
            return
        req.produced = seq if seq is not None else req.produced + 1
        if device.injector is not None:
            device.injector.ack(req)        # progress: stale timers die
        if req.stream_t0 is not None:
            t.stream_rtt_s += now - req.stream_t0
            t.stream_steps += 1
            req.stream_t0 = None
        if req.produced == 1:
            t.t_first_token = now
        req.last_token = tok
        if req.produced >= req.max_new_tokens:
            t.new_tokens = req.produced
            t.t_done = now
            t.clamp_chain()
            device.telemetry.record(t)
            device.server.sim_request_done(req)
            return
        self._schedule_edge_step(device, req)

    def _schedule_edge_step(self, device, req) -> None:
        """Charge one edge decode step on ``device`` and schedule its
        completion — also the migration resume point: a checkpointed decode
        restarts here on its new home."""
        t = req.trace
        now = device.loop.now
        start = max(now, device.free_at)
        dur = device.cost.edge_decode_step_s(t.split, device.d_r)
        device.free_at = start + dur
        t.mobile_energy_mj += device.cost.edge_energy_mj(dur)
        device.tracer.complete(device.track, "decode_step", start,
                               start + dur, cat="edge",
                               args={"uid": t.uid, "pos": req.edge_pos})
        req.state = "edge_decode"
        device.loop.schedule_at(start + dur,
                                lambda: self.edge_step_done(device, req),
                                owner=device)

    def edge_step_done(self, device, req) -> None:
        if req.finished:
            return
        t = req.trace
        if device.bank is not None:
            runner = device.runner(t.split)
            tok = np.asarray([[req.last_token]], np.int32)
            payload, scales, req.edge_cache = runner.edge_step(
                runner.params, tok, req.edge_cache, [req.edge_pos])
            req.stream_row = (payload, scales)
        req.edge_pos += 1
        device.telemetry.counters["stream_edge_steps"] += 1
        self.send_row(device, req)

    def send_row(self, device, req) -> None:
        """One quantized row up the wire; retries re-enter here (the RTT
        anchor keeps the FIRST send time, so a retried token honestly pays
        the loss in its RTT)."""
        if req.finished:
            return
        t = req.trace
        now = device.loop.now
        nbytes = device.cost.stream_row_bytes(device.wire_mode, device.d_r)
        t.wire_bytes += nbytes
        if req.stream_t0 is None:
            req.stream_t0 = now                  # RTT: row ready -> id back
        start, done = device.uplink.transfer(nbytes, now, uid=t.uid,
                                             tag="row")
        t.mobile_energy_mj += device.uplink.transfer_energy_mj(nbytes)
        req.state = "await_token"
        device.loop.schedule_at(done,
                                lambda: device.server.on_stream_row(req),
                                owner=device.uplink)
        if device.injector is not None:
            device.injector.arm(
                req,
                lambda: self.send_row(device.server.device_for(req), req),
                "row")

    # -- cloud side ---------------------------------------------------------
    def start_cloud_decode(self, server, req) -> None:
        """Cloud prefill finished: sample the first token and send it down.
        The first-token timestamp is set when the id reaches the mobile —
        the streamed transport's TTFT honestly includes the downlink."""
        t = req.trace
        if server.bank is not None:
            logits_row, cache1, _ = server._cloud_numerics(req)
            runner = server.bank.runner(t.split)
            req.cloud_cache = runner.pad_decode_cache(cache1, 1,
                                                      server.max_len)
            req.cloud_pos = t.prompt_len
            eng = server._engine(t.split)
            req.engine_req = eng.submit_streamed(
                t.prompt_len, logits_row, max_new_tokens=req.max_new_tokens)
            req.payload = None
            tok = req.engine_req.generated[0]
        else:
            tok = 0
        self.send_token(server, req, tok)

    def serve_rows(self, server, batch) -> None:
        """One serial-accelerator turn over the arrived rows: numerics run
        per request through the engine's single-slot streamed entry (the
        bank-shared compiled cloud step); the turn's duration was already
        charged by the server per split group."""
        for req in batch:
            t = req.trace
            if req.finished:
                continue
            if req.edge_pos <= req.cloud_served_upto:
                # a retried row for a position already served: don't step
                # the numerics again — resend the token it produced
                server.telemetry.counters["fault_duplicate_rows"] += 1
                tok, seq = req.last_sent
                self.send_token(server, req, tok, seq=seq)
                continue
            if server.bank is not None:
                runner = server.bank.runner(t.split)
                payload, scales = req.stream_row
                tok, req.cloud_cache = runner.stream_step(
                    server._engine(t.split), req.engine_req, req.cloud_cache,
                    payload, scales, req.cloud_pos)
            else:
                tok = 0
            req.cloud_pos += 1
            req.cloud_served_upto = req.edge_pos
            self.send_token(server, req, tok)

    def send_token(self, server, req, tok, seq=None) -> None:
        """One sampled id over the downlink to the mobile; on the last token
        the cloud's involvement ends here (slot + cache released before the
        downlink completes).  A fresh send (``seq=None``) assigns the next
        sequence number; a resend reuses the original's, so the device can
        drop duplicates.  Cloud-side bookkeeping (completion stamp, slot
        release, cache drop) runs on the FRESH send only."""
        if req.finished:
            return
        t = req.trace
        now = server.loop.now
        fresh = seq is None
        if fresh:
            req.sent_down += 1
            seq = req.sent_down
            req.last_sent = (int(tok), seq)
        wire = server.wire_for(req)
        t.downlink_bytes += TOKEN_BYTES
        start, done = wire.transfer_down(TOKEN_BYTES, now, uid=t.uid,
                                         tag="token")
        t.mobile_energy_mj += wire.downlink_energy_mj(TOKEN_BYTES)
        if fresh and seq >= req.max_new_tokens:
            t.t_cloud_done = now
            if req.slot >= 0:
                server.release_slot(req, now)
            req.cloud_cache = None
        # resolve the device at FIRE time: a migrated request's token lands
        # on its new home
        server.loop.schedule_at(
            done,
            lambda: self.token_at_device(server.device_for(req), req, tok,
                                         seq),
            owner=wire)
        if server.injector is not None and fresh and seq == 1:
            # the first token has no device-side row timer guarding it
            server.injector.arm(
                req, lambda: self.resend_last_token(server, req), "token")

    def resend_last_token(self, server, req) -> None:
        if req.finished or req.last_sent is None:
            return
        tok, seq = req.last_sent
        self.send_token(server, req, tok, seq=seq)
        server.injector.arm(
            req, lambda: self.resend_last_token(server, req), "token")


class ProgressiveTransport(StreamedTransport):
    """Streamed decode + progressive prefill upload: coarse bitplanes
    first, cloud prefill overlapping the refinement tail.

    The edge side (EdgeDevice._send_progressive) splits the prefill
    payload into two back-to-back FIFO uplink transfers; ``on_payload``
    fires at the COARSE landing, so the cloud's serial prefill frontier
    starts ``refine/link`` seconds earlier than under ``streamed``.  The
    cloud side below runs the exact streamed numerics — the payload object
    always holds the full-precision codes, so generated ids are bitwise
    identical to ``streamed`` — but holds the first sampled token until
    the refinement chunk has landed (``req.refine_done``), keeping the
    modeled timeline honest: no token can depend on planes still in
    flight."""

    name = "progressive"

    def start_cloud_decode(self, server, req) -> None:
        t = req.trace
        if server.bank is not None:
            logits_row, cache1, _ = server._cloud_numerics(req)
            runner = server.bank.runner(t.split)
            req.cloud_cache = runner.pad_decode_cache(cache1, 1,
                                                      server.max_len)
            req.cloud_pos = t.prompt_len
            eng = server._engine(t.split)
            req.engine_req = eng.submit_streamed(
                t.prompt_len, logits_row, max_new_tokens=req.max_new_tokens)
            req.payload = None
            tok = int(req.engine_req.generated[0])
        else:
            tok = 0
        if not req.refine_done:
            # the overlapped prefill beat the refinement tail: hold the
            # token; the refine-landing event releases it (release_gated)
            req.gated_token = tok
            server.telemetry.counters["progressive_gated_tokens"] += 1
            return
        self.send_token(server, req, tok)

    def release_gated(self, server, req) -> None:
        """Refinement landed: unfreeze decode, sending the held first
        token if the prefill already produced one."""
        req.refine_done = True
        if req.finished or req.gated_token is None:
            return
        tok = req.gated_token
        req.gated_token = None
        self.send_token(server, req, tok)


TRANSPORTS = {
    "cache_handoff": CacheHandoffTransport(),
    "streamed": StreamedTransport(),
    "progressive": ProgressiveTransport(),
}


def get_transport(name: str) -> DecodeTransport:
    try:
        return TRANSPORTS[name]
    except KeyError:
        raise KeyError(f"unknown decode transport {name!r}; "
                       f"known: {sorted(TRANSPORTS)}") from None
