"""The paper's experiment at reproducible scale: train ResNet+butterfly
end-to-end for every (split x D_r) on the synthetic image task, reproduce the
Fig. 7 accuracy-vs-D_r trend, then run Algorithm 1 (profile + select) across
3G/4G/Wi-Fi — the miniature of Tables IV/V.

Run:  PYTHONPATH=src python examples/train_resnet_butterfly.py [--steps 150]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet50 import resnet50
from repro.core import costs
from repro.core.planner import (profiling_phase, selection_phase,
                                TrainingPhaseResult)
from repro.core.profiler import GTX_1080TI, JETSON_TX2
from repro.core.wireless import NETWORKS
from repro.data import ImageTaskConfig, SyntheticImages
from repro.models.resnet import forward_resnet, init_resnet
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      constant_schedule)


def train_and_eval(cfg, steps: int, seed: int = 0) -> float:
    params = init_resnet(jax.random.key(seed), cfg)
    task = SyntheticImages(ImageTaskConfig(num_classes=cfg.num_classes,
                                           image_size=cfg.image_size))
    rng = np.random.default_rng(seed)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=constant_schedule(1e-3), weight_decay=1e-4)

    def loss_fn(p, x, y):
        logits = forward_resnet(p, x, cfg, train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1))

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw_update(ocfg, p, g, o)
        return p, o, l

    for _ in range(steps):
        x, y = task.batch(32, rng)
        params, opt, _ = step(params, opt, jnp.asarray(x), jnp.asarray(y))
    xs, ys = task.batch(256, np.random.default_rng(999))
    logits = forward_resnet(params, jnp.asarray(xs), cfg, train=False)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ys)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    base = resnet50().reduced()          # 2 residual blocks at 32x32
    target = train_and_eval(base, args.steps)
    print(f"baseline (no butterfly) accuracy: {target:.3f}")

    # Fig. 7 trend: accuracy vs D_r for each split
    print("\naccuracy vs D_r (paper Fig. 7, miniature):")
    results = {}
    for split in range(1, base.num_blocks + 1):
        row = {}
        for d_r in (1, 2, 4, 8):
            acc = train_and_eval(base.with_butterfly(split, d_r), args.steps)
            row[d_r] = acc
        results[split] = row
        print(f"  after RB{split}: " +
              "  ".join(f"D_r={d}: {a:.3f}" for d, a in row.items()))

    # Algorithm 1 training phase result: minimal D_r within 2% of target
    trained = []
    for split, row in results.items():
        ok = [d for d, a in row.items() if a >= target - 0.02]
        trained.append(TrainingPhaseResult(split, min(ok) if ok else max(row),
                                           row[min(ok) if ok else max(row)]))
        print(f"  minimal D_r for RB{split}: {trained[-1].d_r} "
              f"(acc {trained[-1].accuracy:.3f})")

    # profiling + selection on the FULL ResNet-50 costs (paper's model)
    full = resnet50()
    def split_costs(split, d_r):
        ef, cf, wire = costs.resnet_split_flops(full, split, d_r)
        return ef, ef / 10, cf, cf / 10, wire

    from repro.configs.resnet50 import PAPER_MIN_DR
    trained_full = [TrainingPhaseResult(s, PAPER_MIN_DR[s], 0.74)
                    for s in range(1, 17)]
    profiles = profiling_phase(trained_full, split_costs, JETSON_TX2, GTX_1080TI)
    print("\nAlgorithm 1 selection on full ResNet-50 (paper min-D_r):")
    for net_name, net in NETWORKS.items():
        for objective in ("latency", "energy"):
            sel = selection_phase(profiles, net, objective)
            print(f"  {net_name:5s} {objective:8s}: split after RB{sel.split} "
                  f"(D_r={sel.d_r})  latency {sel.latency_s*1e3:.2f} ms  "
                  f"energy {sel.energy_mj:.2f} mJ")


if __name__ == "__main__":
    main()
