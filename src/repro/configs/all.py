"""Import every per-arch config module so the registry is populated."""
import repro.configs.qwen3_14b      # noqa: F401
import repro.configs.qwen3_8b       # noqa: F401
import repro.configs.llama4_maverick  # noqa: F401
import repro.configs.qwen3_moe      # noqa: F401
import repro.configs.pixtral_12b    # noqa: F401
import repro.configs.whisper_base   # noqa: F401
import repro.configs.gemma_7b       # noqa: F401
import repro.configs.gemma3_12b     # noqa: F401
import repro.configs.xlstm_125m     # noqa: F401
import repro.configs.zamba2_7b      # noqa: F401
import repro.configs.resnet50       # noqa: F401

ASSIGNED = [
    "qwen3-14b",
    "llama4-maverick-400b-a17b",
    "qwen3-moe-235b-a22b",
    "pixtral-12b",
    "whisper-base",
    "gemma-7b",
    "gemma3-12b",
    "qwen3-8b",
    "xlstm-125m",
    "zamba2-7b",
]
