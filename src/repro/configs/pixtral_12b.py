"""pixtral-12b [vlm] — mistral-nemo style decoder consuming pixtral-ViT patch
embeddings.  The vision tower is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed patch embeddings (batch, n_patches,
d_model). [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        arch_type="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        act="silu",
        rope_theta=1e6,
        tie_embeddings=False,
        num_patches=1024,             # stub ViT output: 1024 patch embeddings
        source="hf:mistralai/Pixtral-12B-2409",
    )
