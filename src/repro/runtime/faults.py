"""Fault injection and recovery for the split runtime (DESIGN.md section 15).

A :class:`FaultSchedule` is a seeded, declarative list of events — device
churn (join/leave), link handover (3g→wifi mid-request, with the controller
re-scoring transports), transient wire blackouts, and cloud outage windows —
fired on the virtual clock, so a chaotic run is exactly as deterministic and
replayable as a calm one.  The schedule serializes into the arrival-trace
JSONL header (arrival-trace-v2), so a recorded chaotic run replays
byte-for-byte, fault sequence included.

Recovery is a per-request state machine driven by :class:`FaultInjector`:

* every send (prefill payload, streamed row, streamed token, final ids) arms
  a per-phase timeout; retries resend through the *original* send path with
  capped exponential backoff, and exhausted retries either fail the request
  or degrade it to edge-only fallback when the cloud is dark;
* an evicted device's in-flight requests *migrate* to another device in the
  cell — a mid-decode streamed request is checkpointed
  (:class:`DecodeCheckpoint`: edge stage-0 cache, cloud stage-1 cache,
  sampling state) and resumed on the target bitwise-identically to the
  uninterrupted run;
* a watchdog sweep on the virtual clock fails lost/stuck requests after
  ``request_timeout_s``, so ``Simulation.run`` terminates under any schedule.

Everything here is gated on ``injector is not None``: with no schedule
configured, no timer is armed, no counter is touched, and telemetry is
byte-identical to a build without this module.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("device_leave", "device_join", "handover", "blackout",
               "cloud_outage")

_ALIASES = {
    "leave": "device_leave", "device_leave": "device_leave",
    "join": "device_join", "device_join": "device_join",
    "handover": "handover",
    "blackout": "blackout",
    "outage": "cloud_outage", "cloud_outage": "cloud_outage",
}


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  Which fields matter depends on ``kind``:

    ======================  ==========================================
    ``device_leave``        ``device`` (global device index)
    ``device_join``         ``cell`` (cell name to grow)
    ``handover``            ``cell``, ``network`` (new link model)
    ``blackout``            ``cell``, ``duration`` (seconds dark)
    ``cloud_outage``        ``duration`` (seconds of ingress blackout)
    ======================  ==========================================
    """

    t: float
    kind: str
    cell: str = ""
    device: int = -1
    network: str = ""
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")

    def to_obj(self) -> dict:
        obj = {"t": self.t, "kind": self.kind}
        if self.cell:
            obj["cell"] = self.cell
        if self.device >= 0:
            obj["device"] = self.device
        if self.network:
            obj["network"] = self.network
        if self.duration:
            obj["duration"] = self.duration
        return obj

    @classmethod
    def from_obj(cls, obj: dict) -> "FaultEvent":
        return cls(t=float(obj["t"]), kind=str(obj["kind"]),
                   cell=str(obj.get("cell", "")),
                   device=int(obj.get("device", -1)),
                   network=str(obj.get("network", "")),
                   duration=float(obj.get("duration", 0.0)))


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, time-sorted tuple of :class:`FaultEvent`."""

    events: Tuple[FaultEvent, ...] = ()

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def to_obj(self) -> list:
        return [ev.to_obj() for ev in self.events]

    @classmethod
    def from_obj(cls, obj: list) -> "FaultSchedule":
        return cls(tuple(FaultEvent.from_obj(o) for o in obj))

    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """Parse the ``--faults`` DSL: comma-separated ``kind@t[:arg][+dur]``.

        Examples::

            leave@0.05:2                 device 2 leaves at t=0.05
            join@0.2:3g-jet              a device joins cell "3g-jet"
            handover@0.1:3g-jet>wifi     cell's wire re-links to wifi
            blackout@0.15:3g-jet+0.05    cell's wire dark for 50 ms
            outage@0.3+0.2               cloud ingress dark for 200 ms
        """
        events: List[FaultEvent] = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            kind_s, _, rest = part.partition("@")
            kind = _ALIASES.get(kind_s.strip())
            if kind is None:
                raise ValueError(f"unknown fault kind {kind_s!r} in {part!r}")
            duration = 0.0
            if "+" in rest:
                rest, dur_s = rest.rsplit("+", 1)
                duration = float(dur_s)
            t_s, _, arg = rest.partition(":")
            t = float(t_s)
            cell, device, network = "", -1, ""
            if kind == "device_leave":
                device = int(arg)
            elif kind == "device_join":
                cell = arg
            elif kind == "handover":
                cell, _, network = arg.partition(">")
                if not network:
                    raise ValueError(
                        f"handover needs cell>network, got {arg!r}")
            elif kind == "blackout":
                cell = arg
                if duration <= 0:
                    raise ValueError(f"blackout needs +duration: {part!r}")
            elif kind == "cloud_outage":
                if duration <= 0:
                    raise ValueError(f"outage needs +duration: {part!r}")
            events.append(FaultEvent(t=t, kind=kind, cell=cell, device=device,
                                     network=network, duration=duration))
        return cls(tuple(sorted(events, key=lambda e: (e.t, e.kind))))

    @classmethod
    def random(cls, seed: int, *, cells: Tuple[str, ...] = ("cell0",),
               num_devices: int = 4,
               networks: Tuple[str, ...] = ("3g", "4g", "wifi"),
               n_events: int = 6, horizon: float = 0.4) -> "FaultSchedule":
        """A seeded random schedule for chaos sweeps (namespaced rng so the
        same seed never collides with the arrival-process streams)."""
        rng = np.random.default_rng([0xFA, int(seed)])
        events: List[FaultEvent] = []
        for _ in range(int(n_events)):
            t = float(rng.uniform(0.0, horizon))
            kind = FAULT_KINDS[int(rng.integers(0, len(FAULT_KINDS)))]
            cell = str(cells[int(rng.integers(0, len(cells)))])
            if kind == "device_leave":
                events.append(FaultEvent(
                    t=t, kind=kind, device=int(rng.integers(0, num_devices))))
            elif kind == "device_join":
                events.append(FaultEvent(t=t, kind=kind, cell=cell))
            elif kind == "handover":
                net = str(networks[int(rng.integers(0, len(networks)))])
                events.append(FaultEvent(t=t, kind=kind, cell=cell,
                                         network=net))
            elif kind == "blackout":
                events.append(FaultEvent(
                    t=t, kind=kind, cell=cell,
                    duration=float(rng.uniform(0.01, 0.05))))
            else:  # cloud_outage
                events.append(FaultEvent(
                    t=t, kind=kind, duration=float(rng.uniform(0.02, 0.1))))
        return cls(tuple(sorted(events, key=lambda e: (e.t, e.kind))))


@dataclass(frozen=True)
class RecoveryPolicy:
    """Timeout / retry / fallback knobs for the request state machine."""

    phase_timeout_s: float = 0.5       # armed per send; ack cancels via epoch
    retry_base_s: float = 0.02         # backoff = base * 2^(retries-1) ...
    retry_cap_s: float = 0.2           # ... capped here
    max_retries: int = 4               # cumulative across phases, per request
    edge_fallback: bool = True         # degrade to edge-only when cloud dark
    migration_delay_s: float = 0.02    # checkpoint transfer + warmup cost
    request_timeout_s: float = 10.0    # watchdog hard deadline per request
    watchdog_interval_s: float = 0.5   # sweep period on the virtual clock


@dataclass
class DecodeCheckpoint:
    """Everything needed to resume an in-flight streamed decode elsewhere,
    bitwise-identically: edge stage-0 KV cache + position, cloud stage-1
    cache + position, the sampling state (last token, generated ids), and
    the duplicate-suppression counters of the token protocol.  Caches move
    by reference — the byte cost of moving them is modeled by
    ``RecoveryPolicy.migration_delay_s``, not re-simulated."""

    uid: int
    split: int
    transport: str
    prompt_len: int
    edge_pos: int
    cloud_pos: int
    produced: int
    sent_down: int
    cloud_served_upto: int
    last_token: Optional[int]
    last_sent: Optional[tuple]
    generated: tuple
    edge_cache: object = None
    cloud_cache: object = None
    stream_row: object = None

    @classmethod
    def capture(cls, req) -> "DecodeCheckpoint":
        t = req.trace
        generated = tuple(req.engine_req.generated) if req.engine_req else ()
        return cls(uid=t.uid, split=t.split, transport=t.transport,
                   prompt_len=t.prompt_len, edge_pos=req.edge_pos,
                   cloud_pos=req.cloud_pos, produced=req.produced,
                   sent_down=req.sent_down,
                   cloud_served_upto=req.cloud_served_upto,
                   last_token=req.last_token, last_sent=req.last_sent,
                   generated=generated, edge_cache=req.edge_cache,
                   cloud_cache=req.cloud_cache, stream_row=req.stream_row)

    def restore(self, req) -> None:
        assert req.trace.uid == self.uid, "checkpoint/request uid mismatch"
        req.edge_pos = self.edge_pos
        req.cloud_pos = self.cloud_pos
        req.produced = self.produced
        req.sent_down = self.sent_down
        req.cloud_served_upto = self.cloud_served_upto
        req.last_token = self.last_token
        req.last_sent = self.last_sent
        req.edge_cache = self.edge_cache
        req.cloud_cache = self.cloud_cache
        req.stream_row = self.stream_row


class FaultInjector:
    """Fires a :class:`FaultSchedule` on the simulation's event loop and
    owns the recovery state machine (timeouts, retries, migration, fallback,
    watchdog).  Built only when a schedule/policy is configured, so the
    no-fault path never touches it."""

    def __init__(self, sim, schedule: FaultSchedule,
                 policy: Optional[RecoveryPolicy] = None):
        self.sim = sim
        self.loop = sim.loop
        self.server = sim.server
        self.telemetry = sim.telemetry
        self.schedule = schedule
        self.policy = policy or RecoveryPolicy()
        self._cancel_watchdog: Optional[Callable[[], None]] = None

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        for ev in self.schedule:
            self.loop.schedule_at(max(ev.t, self.loop.now),
                                  (lambda e=ev: self._fire(e)))
        self._cancel_watchdog = self.loop.schedule_every(
            self.policy.watchdog_interval_s, self._watchdog)

    def stop(self) -> None:
        if self._cancel_watchdog is not None:
            self._cancel_watchdog()
            self._cancel_watchdog = None

    def _fire(self, ev: FaultEvent) -> None:
        self.telemetry.counters[f"fault_{ev.kind}s"] += 1
        if self.sim.tracer.enabled:
            self.sim.tracer.instant(
                "faults/sched", ev.kind, self.loop.now, cat="fault",
                args={"kind": ev.kind, "cell": ev.cell, "device": ev.device,
                      "network": ev.network, "duration": ev.duration})
        getattr(self, f"_{ev.kind}")(ev)

    # ------------------------------------------------------------ events

    def _device_leave(self, ev: FaultEvent) -> None:
        if not (0 <= ev.device < len(self.sim.devices)):
            return
        dev = self.sim.devices[ev.device]
        if dev.evicted:
            return
        dev.evicted = True
        self.loop.cancel_owner(dev)
        target = self._target(dev.cell_index)
        pol = self.policy
        for req in self.sim.requests:
            if req.finished or req.home != dev.dev_id:
                continue
            if target is None:
                self.fail(req, "device_lost")
                continue
            req.trace.migrations += 1
            self.telemetry.counters["fault_migrations"] += 1
            req.home = target.dev_id
            tgt = target
            if req.state == "edge_compute":
                if req in dev._numerics_pending:
                    dev._numerics_pending.remove(req)
                self.loop.schedule(pol.migration_delay_s,
                                   (lambda r=req, d=tgt:
                                    d.restart_prefill(r)), owner=tgt)
            elif req.state == "edge_decode":
                # checkpoint once; a re-eviction before resume reuses it
                ckpt = req.checkpoint or DecodeCheckpoint.capture(req)
                req.checkpoint = ckpt
                req.edge_cache = req.cloud_cache = req.stream_row = None
                self.telemetry.counters["fault_decode_migrations"] += 1

                def resume(r=req, d=tgt, c=ckpt):
                    if r.finished:
                        return
                    c.restore(r)
                    r.checkpoint = None
                    from repro.runtime.transports import get_transport
                    get_transport(r.trace.transport)._schedule_edge_step(d, r)

                self.loop.schedule(pol.migration_delay_s, resume, owner=tgt)
            elif req.state == "edge_fallback":
                self.loop.schedule(pol.migration_delay_s,
                                   (lambda r=req, d=tgt:
                                    d.fallback_local(r)), owner=tgt)
            # uplink / await_token / cloud / downlink: frames already in
            # flight (or cloud-side); re-homing is enough — resends and
            # deliveries resolve the device via server.device_for at fire
            # time, and the phase timers cover lost frames.

    def _device_join(self, ev: FaultEvent) -> None:
        from repro.runtime.actors import EdgeDevice
        cell = next((c for c in self.sim.cells if c.name == ev.cell), None)
        if cell is None:
            return
        sc = self.sim.sim_cfg
        dev = EdgeDevice(
            len(self.sim.devices), loop=self.loop, cost=cell.cost,
            uplink=cell.wire, server=self.server, bank=self.sim.bank,
            mode=sc.mode, wire_mode=sc.wire_mode, d_r=sc.d_r,
            telemetry=self.telemetry, numerics_split=cell.current_split,
            cell=cell.name, cell_index=cell.index)
        dev.free_at = self.loop.now
        dev.tracer = self.sim.tracer
        dev.injector = self
        if self.sim.tracer.enabled:
            self.sim.tracer.track(dev.track)
        # shared list: the server's delivery targets grow with the fleet
        self.sim.devices.append(dev)

    def _handover(self, ev: FaultEvent) -> None:
        cell = next((c for c in self.sim.cells if c.name == ev.cell), None)
        if cell is None:
            return
        wire = cell.wire
        wire.handover(ev.network)
        # every controller whose cell shares this wire re-scores transports
        for c in self.sim.cells:
            if c.wire is wire and c.controller is not None:
                c.controller.poke(self.loop.now, reason="handover")

    def _blackout(self, ev: FaultEvent) -> None:
        cell = next((c for c in self.sim.cells if c.name == ev.cell), None)
        if cell is None:
            return
        wire = cell.wire
        wire.blackout(self.loop.now, ev.duration)
        lost = self.loop.cancel_owner(wire)
        if lost:
            self.telemetry.counters["fault_lost_frames"] += lost

    def _cloud_outage(self, ev: FaultEvent) -> None:
        srv = self.server
        srv.outage_until = max(srv.outage_until, self.loop.now + ev.duration)
        if srv.pending:
            self.telemetry.counters["fault_outage_dropped_payloads"] += \
                len(srv.pending)
            srv.pending.clear()
        if srv.stream_ready:
            self.telemetry.counters["fault_outage_dropped_rows"] += \
                len(srv.stream_ready)
            srv.stream_ready.clear()

    # ------------------------------------------------------------ routing

    def route(self, dev_id: int) -> int:
        """Arrival-time rerouting: an evicted device's arrivals land on the
        lowest live device in its cell (or -1 when the cell is empty)."""
        dev = self.sim.devices[dev_id]
        if not dev.evicted:
            return dev_id
        self.telemetry.counters["fault_rerouted_arrivals"] += 1
        target = self._target(dev.cell_index)
        return -1 if target is None else target.dev_id

    def _target(self, cell_index: int):
        for d in self.sim.devices:
            if d.cell_index == cell_index and not d.evicted:
                return d
        return None

    # ------------------------------------------------------- state machine

    def arm(self, req, resend: Callable[[], None], label: str) -> None:
        """Arm a per-phase timeout for the send that just happened.  The
        matching ack is an epoch bump (:meth:`ack`); a stale or finished
        timer is a no-op.  On expiry: capped-exponential-backoff resend
        through the original send path, until the per-request retry budget
        runs out — then edge fallback (cloud phases, nothing streamed yet)
        or failure."""
        epoch = req.epoch
        pol = self.policy

        def fire():
            if req.finished or req.epoch != epoch:
                return
            if req.retries >= pol.max_retries:
                if (pol.edge_fallback and req.produced == 0
                        and req.trace.mode == "split"
                        and label in ("payload", "token")):
                    self.fallback(req)
                else:
                    self.fail(req, f"{label}_retries_exhausted")
                return
            req.retries += 1
            req.trace.retries += 1
            self.telemetry.counters["fault_retries"] += 1
            backoff = min(pol.retry_base_s * (2.0 ** (req.retries - 1)),
                          pol.retry_cap_s)
            self.sim.registry.histogram("fault_backoff_s").observe(backoff)

            def go():
                if req.finished or req.epoch != epoch:
                    return
                resend()

            self.loop.schedule(backoff, go)

        self.loop.schedule(pol.phase_timeout_s, fire)

    def ack(self, req) -> None:
        """Progress happened — invalidate every timer armed before now."""
        req.epoch += 1

    def fallback(self, req) -> None:
        """Degrade to edge-only: abandon the cloud half and run the full
        model locally on a live device in the request's cell."""
        if req.finished:
            return
        req.epoch += 1
        if req.slot >= 0:
            self.server.release_slot(req)
        if req in self.server.pending:
            self.server.pending.remove(req)
        dev = self.server.device_for(req)
        if dev is None or dev.evicted:
            dev = self._target(dev.cell_index) if dev is not None else None
            if dev is None:
                self.fail(req, "no_device_for_fallback")
                return
            req.home = dev.dev_id
        req.trace.fallback = "edge"
        self.telemetry.counters["fault_edge_fallbacks"] += 1
        dev.fallback_local(req)

    def fail(self, req, reason: str) -> None:
        if req.finished:
            return
        req.epoch += 1
        t = req.trace
        t.outcome = "failed"
        t.failure = reason
        t.t_done = self.loop.now
        t.clamp_chain()
        self.telemetry.counters["fault_failed_requests"] += 1
        if req.slot >= 0:
            self.server.release_slot(req)
        self.telemetry.record(t)
        self.server.sim_request_done(req)

    def _watchdog(self) -> None:
        deadline = self.policy.request_timeout_s
        now = self.loop.now
        for req in self.sim.requests:
            if req.finished or req.state == "new":
                continue
            if now - req.trace.t_arrival > deadline:
                self.fail(req, "request_timeout")
