"""Data pipelines (offline container: procedurally generated datasets).

LM stream       — a Zipfian Markov-chain language whose bigram structure a
                  small LM can learn (loss decreases measurably in ~100 steps),
                  used by the end-to-end training example.
Image dataset   — the synthetic classification task for the ResNet/butterfly
                  reproduction of the paper's Fig. 7: each class is a distinct
                  oriented-grating + color pattern with additive noise, so
                  accuracy is a meaningful signal at small scale.

Both pipelines are deterministic in seed, yield numpy, and shard the leading
batch dim via jax.device_put with the launcher-provided sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

# ---------------------------------------------------------------------------
# LM stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 4       # out-degree of the Markov chain


class MarkovLMStream:
    """Zipfian Markov chain over the vocab: learnable synthetic language."""

    def __init__(self, cfg: LMStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab_size, cfg.branching
        self.next_tokens = rng.integers(0, V, size=(V, B), dtype=np.int32)
        probs = 1.0 / np.arange(1, B + 1)
        self.next_probs = probs / probs.sum()
        self.rng = rng

    def _walk(self, n: int) -> np.ndarray:
        V, B = self.cfg.vocab_size, self.cfg.branching
        out = np.empty(n, np.int32)
        tok = int(self.rng.integers(0, V))
        choices = self.rng.choice(B, size=n, p=self.next_probs)
        for i in range(n):
            out[i] = tok
            tok = int(self.next_tokens[tok, choices[i]])
        return out

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        c = self.cfg
        toks = np.stack([self._walk(c.seq_len + 1) for _ in range(c.batch_size)])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


def lm_batches(vocab_size: int, seq_len: int, batch_size: int, seed: int = 0):
    return MarkovLMStream(LMStreamConfig(vocab_size, seq_len, batch_size, seed))


# ---------------------------------------------------------------------------
# synthetic image classification (ResNet / Fig. 7 reproduction)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImageTaskConfig:
    num_classes: int = 10
    image_size: int = 32
    noise: float = 0.35
    seed: int = 0


class SyntheticImages:
    """Class = (orientation, frequency, color) grating + noise."""

    def __init__(self, cfg: ImageTaskConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        n = cfg.num_classes
        self.angles = rng.uniform(0, np.pi, n)
        self.freqs = rng.uniform(2.0, 6.0, n)
        self.colors = rng.uniform(0.3, 1.0, (n, 3))
        self.phases = rng.uniform(0, 2 * np.pi, n)

    def batch(self, batch_size: int, rng: np.random.Generator):
        c = self.cfg
        ys = rng.integers(0, c.num_classes, batch_size)
        xs = np.empty((batch_size, c.image_size, c.image_size, 3), np.float32)
        grid = np.linspace(-1, 1, c.image_size)
        gx, gy = np.meshgrid(grid, grid)
        for i, y in enumerate(ys):
            a, f, ph = self.angles[y], self.freqs[y], self.phases[y]
            pattern = np.sin(f * (np.cos(a) * gx + np.sin(a) * gy) * np.pi + ph)
            img = pattern[..., None] * self.colors[y][None, None, :]
            img = img + rng.normal(0, c.noise, img.shape)
            xs[i] = img
        return xs.astype(np.float32), ys.astype(np.int32)


def image_batches(batch_size: int, cfg: Optional[ImageTaskConfig] = None,
                  seed: int = 1) -> Iterator[tuple]:
    cfg = cfg or ImageTaskConfig()
    task = SyntheticImages(cfg)
    rng = np.random.default_rng(seed)
    while True:
        yield task.batch(batch_size, rng)


# ---------------------------------------------------------------------------
# device placement with shardings
# ---------------------------------------------------------------------------


def shard_batch(batch: dict, sharding=None):
    import jax
    if sharding is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), batch, sharding)
