"""Split-serving runtime: an event-driven edge/cloud request simulator.

The paper's headline numbers come from *deploying* the butterfly split under
request traffic and adapting the partition point to server load (Sec. III-C).
This package provides the missing request-stream layer on top of the repo's
static pieces:

  clock.py       deterministic discrete-event loop (reproducible traces)
  wire.py        contended uplink + downlink, windowed goodput feedback
  telemetry.py   per-request breakdown, p50/p95/p99, per-cell fairness
  tracing.py     flight recorder: virtual-clock spans -> Chrome trace JSON
  metrics.py     counters/gauges/histograms, fixed-interval sampler, and
                 opt-in wall-clock jit profiling
  split_exec.py  real jax numerics for the edge/cloud halves + cost model
  transports.py  pluggable decode transports (cache handoff vs streamed rows)
  actors.py      edge-device fleets and the cloud continuous-batching server
  controller.py  per-cell adaptive split + transport control (pluggable
                 objectives: latency / energy / energy_under_slo)
  gateway.py     serving gateway: SLO classes, admission control, circuit
                 breakers, hedged retries, response cache, autoscaling
  simulator.py   multi-cell topologies (CellSpec grammar), workload specs
                 (Poisson/Pareto/diurnal/flash), arrival-trace
                 record/replay, and the runnable simulation

Entry points: ``repro.launch.runtime_sim`` (CLI) and
``benchmarks.run runtime`` (JSON comparison vs cloud-only offload).

The package surface below is THE public API (audited: every name is
re-documented in DESIGN.md section 17 and tests/test_workload.py asserts
the two lists match); anything not exported here is an internal detail
that may change between PRs.
"""
from repro.runtime.actors import CloudServer, CloudSpec, EdgeDevice
from repro.runtime.clock import EventLoop
from repro.runtime.controller import AdaptiveSplitController
from repro.runtime.gateway import (CircuitBreaker, Gateway, GatewayPolicy,
                                   JobQueue, ResponseCache)
from repro.runtime.metrics import (JitProfiler, MetricsRegistry,
                                   MetricsSampler, read_metrics_jsonl)
from repro.runtime.simulator import (Arrival, CellSpec, SimConfig, Simulation,
                                     Topology, WorkloadSpec, build_arrivals,
                                     diurnal_arrivals, flash_arrivals,
                                     pareto_arrivals, parse_topology,
                                     poisson_arrivals, record_arrivals,
                                     run_sim, trace_arrivals)
from repro.runtime.telemetry import RequestTrace, Telemetry
from repro.runtime.tracing import Tracer, validate_chrome_trace
from repro.runtime.transports import DecodeTransport, get_transport
from repro.runtime.wire import Wire

__all__ = [
    # simulation driver + config
    "SimConfig", "Simulation", "run_sim",
    # topology + workload
    "Arrival", "CellSpec", "Topology", "parse_topology", "WorkloadSpec",
    "build_arrivals", "poisson_arrivals", "pareto_arrivals",
    "diurnal_arrivals", "flash_arrivals", "record_arrivals",
    "trace_arrivals",
    # actors + gateway
    "CloudServer", "CloudSpec", "EdgeDevice", "Gateway", "GatewayPolicy",
    "JobQueue", "CircuitBreaker", "ResponseCache",
    # control + transport + wire
    "AdaptiveSplitController", "DecodeTransport", "get_transport", "Wire",
    # clock + observability
    "EventLoop", "RequestTrace", "Telemetry", "Tracer",
    "validate_chrome_trace", "MetricsRegistry", "MetricsSampler",
    "JitProfiler", "read_metrics_jsonl",
]
