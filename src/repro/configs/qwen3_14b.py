"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig, register


@register("qwen3-14b")
def qwen3_14b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        arch_type="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        act="silu",
        rope_theta=1e6,
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-8B (family card, 14B row)",
    )
