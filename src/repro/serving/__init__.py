from repro.serving.engine import Request, ServingEngine
from repro.serving.pipeline import make_split_pipeline, wire_stats

__all__ = ["Request", "ServingEngine", "make_split_pipeline", "wire_stats"]
