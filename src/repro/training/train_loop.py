"""Training loop: loss, train_step (value_and_grad + AdamW), eval.

``make_train_step`` returns a pure function suitable for jit/pjit; the
launcher decides shardings.  MoE aux losses (load-balance, router-z) are
added to the LM loss; the butterfly unit, when configured, trains end-to-end
through the straight-through wire quantizer (the paper's key property).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.parallel import LOCAL, ParallelContext
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_loss_fn(built: M.BuiltModel, pctx: ParallelContext = LOCAL,
                 use_kernel: bool = False):
    bf = built.cfg.butterfly
    rate_weight = bf.rate_weight if bf is not None else 0.0

    def loss_fn(params, batch):
        logits, aux = M.forward_train(params, built, batch, pctx, use_kernel)
        # next-token objective: batch["targets"] is already shifted by the
        # data pipeline (targets[t] = tokens[t+1], -1 where masked)
        loss = M.lm_loss(logits, batch["targets"])
        rate = aux["wire_rate_bits"]
        total = loss + aux["load_balance"] + aux["router_z"] + rate_weight * rate
        metrics = {"loss": loss, "load_balance": aux["load_balance"],
                   "router_z": aux["router_z"], "wire_rate_bits": rate}
        return total, metrics
    return loss_fn


def make_train_step(built: M.BuiltModel, opt_cfg: AdamWConfig,
                    pctx: ParallelContext = LOCAL, use_kernel: bool = False,
                    remat: bool = False, accum_steps: int = 1):
    """``accum_steps > 1`` — gradient accumulation: the batch's leading dim
    is split into ``accum_steps`` microbatches scanned sequentially; grads
    are averaged before the single optimizer update.  Cuts peak activation
    memory ~accum_steps x for the same global batch."""
    loss_fn = make_loss_fn(built, pctx, use_kernel)
    if remat:
        loss_fn = jax.checkpoint(loss_fn)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (total, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape(accum_steps, x.shape[0] // accum_steps,
                                 *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                g_sum, t_sum, m_sum = carry
                (t, m), g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, g_sum, g), t_sum + t,
                        jax.tree.map(jnp.add, m_sum, m)), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": 0.0, "load_balance": 0.0, "router_z": 0.0,
                       "wire_rate_bits": 0.0}
            (g_sum, total, m_sum), _ = jax.lax.scan(
                body, (zeros_g, 0.0, zeros_m), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            total = total / accum_steps
            metrics = jax.tree.map(lambda m: m / accum_steps, m_sum)
        params, opt_state, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, total=total, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_eval_step(built: M.BuiltModel, pctx: ParallelContext = LOCAL):
    loss_fn = make_loss_fn(built, pctx)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


def init_train_state(key, built: M.BuiltModel):
    params, specs = M.init_model(key, built)
    opt_state = adamw_init(params)
    return params, opt_state, specs


def opt_state_specs(param_specs):
    """Optimizer-state shardings mirror the param shardings."""
    from jax.sharding import PartitionSpec as P
    return {"mu": param_specs, "nu": param_specs, "step": P()}
