"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def butterfly_reduce_quant_ref(x, w_reduce, bits: int = 8):
    """x: (T, d), w_reduce: (d, d_r) -> (codes int8 (T, d_r), scales f32 (T, 1))."""
    qmax = 2 ** (bits - 1) - 1
    r = (x.astype(jnp.float32) @ w_reduce.astype(jnp.float32))
    absmax = jnp.max(jnp.abs(r), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(r / scale), -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def butterfly_reduce_quant_bincount_ref(x, w_reduce, bits: int = 8):
    """Unfused oracle for the quant+bincount kernel: reduce_quant, then a
    per-channel histogram of the symbol view (code + qmax + 1) of the codes.
    Returns (codes (T, d_r) int8, scales (T, 1) f32, counts (d_r, 2**bits)
    int32)."""
    qmax = 2 ** (bits - 1) - 1
    nsym = 1 << bits
    codes, scales = butterfly_reduce_quant_ref(x, w_reduce, bits)
    sym = codes.astype(jnp.int32) + (qmax + 1)
    ks = jnp.arange(nsym, dtype=jnp.int32)[None, None, :]
    counts = jnp.sum((sym[:, :, None] == ks).astype(jnp.int32), axis=0)
    return codes, scales, counts


def butterfly_dequant_restore_ref(codes, scales, w_restore, out_dtype=jnp.float32):
    """codes: (T, d_r) int8, scales (T, 1) -> (T, d)."""
    r = codes.astype(jnp.float32) * scales
    return (r @ w_restore.astype(jnp.float32)).astype(out_dtype)


def rms_norm_ref(x, weight, eps: float = 1e-6):
    """The model's RMSNorm (gemma-style 1+w weight), restated here so the
    kernel oracles don't import the model package."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def butterfly_restore_norm_ref(codes, scales, w_restore, norm_w,
                               eps: float = 1e-6, out_dtype=jnp.float32):
    """Unfused oracle for the restore+norm kernel: dequant+restore, then the
    model RMSNorm on the restored activation.  Returns (x, h)."""
    x = butterfly_dequant_restore_ref(codes, scales, w_restore, out_dtype)
    return x, rms_norm_ref(x, norm_w, eps)


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: Optional[int] = None):
    """q: (B,S,N,hd), k/v: (B,T,K,hd) with N % K == 0 -> (B,S,N,hd) f32 math."""
    B, S, N, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = N // K
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None] + (T - S)     # align ends (prefill continuation)
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, N, hd).astype(q.dtype)
