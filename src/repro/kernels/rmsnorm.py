"""Fused RMSNorm Pallas kernel (row-tiled).

RMSNorm runs 2x per layer on every architecture here; unfused it costs three
HBM round trips (square/mean, rsqrt-scale, weight-mul).  The kernel keeps a
(TM, d) tile in VMEM and does the whole normalization in-register, writing
each row back exactly once.  Gemma-style zero-centered weight (out uses
``1 + w``) to match models/common.rms_norm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_kernel(x, w, *, eps: float = 1e-6, block_t: int = 256,
                   interpret: bool = False):
    """x: (T, d); w: (d,) zero-centered weight -> (T, d) same dtype as x."""
    T, d = x.shape
    assert T % block_t == 0, (T, block_t)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(x, w)
