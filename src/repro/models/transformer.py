"""Segment/scan-based layer stack shared by all assigned architectures.

A model is a flat list of ``LayerDef``s compressed into ``Segment``s (a
repeating unit scanned with stacked params) so the lowered HLO is O(#segment
kinds), not O(depth) — this keeps 94-layer compiles fast.  The butterfly
split cuts the flat list at the configured boundary, producing two stages;
the butterfly unit (the paper's contribution) runs between them.

Layer kinds: mixer in {attn, mamba, mlstm, slstm} x ffn in {mlp, moe, None};
``shared=True`` marks zamba2's shared-parameter attention block; ``cross``
adds whisper-style cross attention.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import apply_mlp, init_mlp, init_rms_norm, rms_norm
from repro.models.parallel import LOCAL, ParallelContext, model_psum

# Dry-run knob: when True, segment scans fully unroll so XLA's cost analysis
# (which counts while-loop bodies once) reports exact per-step FLOPs/bytes.
# An int k unrolls k iterations per while step (the two-point scan-correction
# probe in launch/dryrun.py). Training/serving keep scans rolled.
SCAN_UNROLL = False


def _scan_unroll(repeats: int) -> int:
    if SCAN_UNROLL is True:
        return repeats
    if SCAN_UNROLL:
        return min(int(SCAN_UNROLL), repeats)
    return 1

# ---------------------------------------------------------------------------
# layer defs and segmentation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerDef:
    mixer: str                      # attn | mamba | mlstm | slstm
    ffn: Optional[str] = "mlp"      # mlp | moe | None
    window: Optional[int] = None
    shared: bool = False            # zamba2 shared-attention params
    cross: bool = False             # whisper decoder cross-attention


@dataclass(frozen=True)
class Segment:
    unit: Tuple[LayerDef, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.repeats


def build_layer_defs(cfg: ModelConfig, long_mode: bool = False) -> List[LayerDef]:
    """The flat per-layer spec for an architecture.

    ``long_mode`` — the long_500k sub-quadratic variant: every attention layer
    runs with a bounded window (cfg.long_context_window)."""
    defs: List[LayerDef] = []
    for i in range(cfg.num_layers):
        if cfg.xlstm is not None:
            every = cfg.xlstm.slstm_every
            mixer = "slstm" if (i % every == every - 1) else "mlstm"
            defs.append(LayerDef(mixer=mixer, ffn=None))
            continue
        if cfg.hybrid_attn_every is not None:
            if i % cfg.hybrid_attn_every == cfg.hybrid_attn_every - 1:
                window = cfg.long_context_window if long_mode else None
                defs.append(LayerDef(mixer="attn", ffn="mlp", shared=True,
                                     window=window))
            else:
                defs.append(LayerDef(mixer="mamba", ffn=None))
            continue
        if cfg.arch_type == "ssm" and cfg.ssm is not None:
            defs.append(LayerDef(mixer="mamba", ffn=None))
            continue
        # attention archs
        window = None
        if cfg.sliding_window is not None:
            if cfg.global_every is None or (i % cfg.global_every != cfg.global_every - 1):
                window = cfg.sliding_window
            elif long_mode:
                window = cfg.long_context_window
        elif long_mode and cfg.long_context_window is not None:
            window = cfg.long_context_window
        ffn = "mlp"
        if cfg.moe is not None and (i % cfg.moe.every == cfg.moe.every - 1):
            ffn = "moe"
        defs.append(LayerDef(mixer="attn", ffn=ffn, window=window,
                             cross=cfg.is_encdec))
    return defs


def segmentize(defs: Sequence[LayerDef]) -> List[Segment]:
    """Compress a flat def list into repeated-unit segments (greedy)."""
    defs = list(defs)
    if not defs:
        return []
    best = None
    for u in range(1, min(len(defs), 8) + 1):
        unit = tuple(defs[:u])
        reps = 1
        while (reps + 1) * u <= len(defs) and tuple(defs[reps * u:(reps + 1) * u]) == unit:
            reps += 1
        covered = reps * u
        # prefer covering more layers with fewer scans; tie-break small unit
        score = (covered, -u)
        if best is None or score > best[0]:
            best = (score, unit, reps)
    _, unit, reps = best
    head = [Segment(unit=unit, repeats=reps)]
    return head + segmentize(defs[len(unit) * reps:])


def split_defs(defs: Sequence[LayerDef], boundary: Optional[int]) -> List[List[Segment]]:
    """Stage list for a butterfly at ``boundary`` (layers [0,b) | [b,N))."""
    if boundary is None:
        return [segmentize(defs)]
    assert 0 < boundary < len(defs), boundary
    return [segmentize(defs[:boundary]), segmentize(defs[boundary:])]


# ---------------------------------------------------------------------------
# layer-range views over a full stacked stage (shared-weight split bank)
# ---------------------------------------------------------------------------


def _range_spans(segments: Sequence[Segment], lo: int, hi: int):
    """Walk a stage's segmentation and yield, for flat layers [lo, hi), either
    aligned repeat-slices or per-layer peels:

      ("slice", seg_index, rep_lo, rep_hi)    — whole repeats [rep_lo, rep_hi)
      ("peel",  seg_index, rep, pos_in_unit)  — one layer of one repeat

    A boundary that lands inside a repeat unit peels individual layers so any
    0 < boundary < N is representable (zamba2-style multi-layer units)."""
    base = 0
    for si, seg in enumerate(segments):
        u = len(seg.unit)
        span = u * seg.repeats
        s, e = max(lo, base) - base, min(hi, base + span) - base
        if s < e:
            # peel only the unaligned head/tail remainders; the aligned
            # middle keeps its stacked-repeat scan
            head = min(e, (s + u - 1) // u * u)
            tail = max(head, e // u * u)
            for li in range(s, head):
                yield ("peel", si, li // u, li % u)
            if head < tail:
                yield ("slice", si, head // u, tail // u)
            for li in range(tail, e):
                yield ("peel", si, li // u, li % u)
        base += span


def range_segments(segments: Sequence[Segment], lo: int, hi: int) -> List[Segment]:
    """Segmentation of the flat layer range [lo, hi) of a full stage; the
    structure matches what :func:`slice_stage_params` produces, so cache
    templates built from it line up with the sliced params."""
    out: List[Segment] = []
    for span in _range_spans(segments, lo, hi):
        if span[0] == "slice":
            _, si, r0, r1 = span
            out.append(Segment(unit=segments[si].unit, repeats=r1 - r0))
        else:
            _, si, _, pos = span
            out.append(Segment(unit=(segments[si].unit[pos],), repeats=1))
    return out


def slice_stage_params(segments: Sequence[Segment], stage_params, lo: int,
                       hi: int):
    """Restrict a stage's stacked params to flat layers [lo, hi).

    Returns ``(segments', params')`` where every leaf of ``params'`` is a
    static slice of the corresponding full stacked leaf — under jit these are
    views into the one shared backbone, so materializing every candidate
    split never copies the parameter set."""
    out_segs: List[Segment] = []
    out_params = []
    for span in _range_spans(segments, lo, hi):
        if span[0] == "slice":
            _, si, r0, r1 = span
            out_segs.append(Segment(unit=segments[si].unit, repeats=r1 - r0))
            out_params.append([jax.tree.map(lambda a: a[r0:r1], up)
                               for up in stage_params[si]])
        else:
            _, si, rep, pos = span
            out_segs.append(Segment(unit=(segments[si].unit[pos],), repeats=1))
            out_params.append([jax.tree.map(lambda a: a[rep:rep + 1],
                                            stage_params[si][pos])])
    return out_segs, out_params


def apply_layer_range(segments: Sequence[Segment], stage_params, x, lo: int,
                      hi: int, *, cfg, pctx, mode, range_cache, pos,
                      enc_out=None, shared_params=None, use_kernel=False,
                      causal=True, first_h=None, overlap_psum=False):
    """Run flat layers [lo, hi) of a full stacked stage.  ``range_cache``
    must be structured per :func:`range_segments` (see init_stage_cache).
    ``first_h`` feeds a pre-computed norm1 output (the fused restore+norm
    kernel) to layer ``lo``; ``overlap_psum`` defers each dense layer's MLP
    psum into the next layer (see :func:`apply_layer`)."""
    segs, params = slice_stage_params(segments, stage_params, lo, hi)
    return apply_stage(segs, params, x, cfg=cfg, pctx=pctx, mode=mode,
                       stage_cache=range_cache, pos=pos, enc_out=enc_out,
                       shared_params=shared_params, use_kernel=use_kernel,
                       causal=causal, first_h=first_h,
                       overlap_psum=overlap_psum)


def first_layer_norm1(segments: Sequence[Segment], stage_params, lo: int = 0):
    """The norm1 weight of flat layer ``lo`` of a stacked stage — what the
    fused dequant+restore+norm kernel needs to pre-compute that layer's
    input norm at the butterfly boundary."""
    for span in _range_spans(segments, lo, lo + 1):
        if span[0] == "peel":
            _, si, rep, pos = span
            return stage_params[si][pos]["norm1"][rep]
        _, si, r0, _ = span
        return stage_params[si][0]["norm1"][r0]
    raise ValueError(f"layer {lo} out of range")


# ---------------------------------------------------------------------------
# tensor-parallel (model-axis) sharding specs for manual shard_map stages
# ---------------------------------------------------------------------------


def check_tp_divisibility(defs: Sequence[LayerDef], cfg: ModelConfig,
                          mp: int) -> None:
    """Model-parallel stages shard whole attention heads, whole d_ff columns
    and whole experts — fail loudly when ``mp`` can't divide them.  Mixers
    without a tensor-parallel decomposition here (mamba/xlstm) replicate and
    run redundantly per rank, so they impose no constraint."""
    if mp <= 1:
        return
    for ldef in defs:
        if ldef.mixer != "attn":
            continue
        if ldef.cross:
            raise ValueError("tensor-parallel stages do not support "
                             "cross-attention layers")
        if attn._padded_heads(cfg) % mp or cfg.num_kv_heads % mp:
            raise ValueError(
                f"model axis {mp} must divide heads "
                f"({attn._padded_heads(cfg)}) and kv heads "
                f"({cfg.num_kv_heads})")
        if (ldef.ffn == "mlp" or ldef.shared) and cfg.d_ff % mp:
            raise ValueError(f"model axis {mp} must divide d_ff ({cfg.d_ff})")
        if ldef.ffn == "moe" and cfg.moe.num_experts % mp:
            raise ValueError(f"model axis {mp} must divide num_experts "
                             f"({cfg.moe.num_experts})")


def tp_layer_specs(ldef: LayerDef, cfg: ModelConfig, dtype,
                   axis: str = "model"):
    """PartitionSpec tree for one layer's params with attention heads, d_ff
    and experts sharded over ``axis`` (everything else replicated) — the
    in_specs a manual shard_map stage feeds params through.  Structure
    mirrors :func:`init_layer` exactly (built by replicating the init spec
    tree, then overriding the shardable projections)."""
    specs = jax.tree.map(lambda _: P(), layer_specs(ldef, cfg, dtype),
                         is_leaf=lambda s: isinstance(s, P))
    if ldef.mixer == "attn" and not ldef.shared:
        specs["mixer"] = attn.tp_attention_specs(cfg, axis)
    if ldef.mixer == "attn" and ldef.ffn == "mlp" and not ldef.shared:
        specs["ffn"] = tp_mlp_specs(axis)
    elif ldef.mixer == "attn" and ldef.ffn == "moe":
        specs["ffn"]["wg"] = P(axis, None, None)   # expert dim -> model axis
        specs["ffn"]["wu"] = P(axis, None, None)
        specs["ffn"]["wd"] = P(axis, None, None)
        # router (and the shared expert, when present) stay replicated: their
        # outputs are full, so only the routed-expert partials get psum'd
    return specs


def tp_mlp_specs(axis: str = "model") -> dict:
    return {"w_gate": P(None, axis), "w_up": P(None, axis),
            "w_down": P(axis, None)}


def tp_stage_specs(segments: Sequence[Segment], cfg: ModelConfig, dtype,
                   axis: str = "model"):
    """Spec tree matching :func:`init_segment` stacking for a whole stage
    (leading repeats dim unsharded)."""
    out = []
    for seg in segments:
        out.append([_prepend_none(tp_layer_specs(ldef, cfg, dtype, axis))
                    for ldef in seg.unit])
    return out


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _prepend_none(spec_tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def init_layer(key, ldef: LayerDef, cfg: ModelConfig, dtype):
    params: dict = {}
    specs: dict = {}
    ks = iter(jax.random.split(key, 8))
    params["norm1"], specs["norm1"] = init_rms_norm(cfg.d_model, dtype)
    if ldef.mixer == "attn":
        if not ldef.shared:   # shared params are stored once at the top level
            params["mixer"], specs["mixer"] = attn.init_attention(next(ks), cfg, dtype)
        if ldef.cross:
            params["norm_cross"], specs["norm_cross"] = init_rms_norm(cfg.d_model, dtype)
            params["cross"], specs["cross"] = attn.init_attention(next(ks), cfg, dtype)
    elif ldef.mixer == "mamba":
        params["mixer"], specs["mixer"] = ssm_lib.init_mamba(next(ks), cfg, dtype)
    elif ldef.mixer == "mlstm":
        params["mixer"], specs["mixer"] = xlstm_lib.init_mlstm(next(ks), cfg, dtype)
    elif ldef.mixer == "slstm":
        params["mixer"], specs["mixer"] = xlstm_lib.init_slstm(next(ks), cfg, dtype)
    else:
        raise ValueError(ldef.mixer)
    if ldef.ffn is not None and ldef.mixer == "attn":
        params["norm2"], specs["norm2"] = init_rms_norm(cfg.d_model, dtype)
        if ldef.ffn == "mlp" and not ldef.shared:
            params["ffn"], specs["ffn"] = init_mlp(next(ks), cfg.d_model, cfg.d_ff, dtype)
        elif ldef.ffn == "moe":
            params["ffn"], specs["ffn"] = moe_lib.init_moe(next(ks), cfg, dtype)
    return params, specs


def layer_specs(ldef: LayerDef, cfg: ModelConfig, dtype):
    """Sharding specs for one layer, computed without allocating params."""
    captured = {}

    def fn(k):
        p, s = init_layer(k, ldef, cfg, dtype)
        captured["s"] = s
        return p

    jax.eval_shape(fn, jax.random.key(0))
    return captured["s"]


def init_segment(key, seg: Segment, cfg: ModelConfig, dtype):
    """Returns ([params per unit pos, stacked over repeats], matching specs)."""
    unit_params, unit_specs = [], []
    keys = jax.random.split(key, len(seg.unit))
    for ldef, k in zip(seg.unit, keys):
        rep_keys = jax.random.split(k, seg.repeats)
        p = jax.vmap(lambda kk: init_layer(kk, ldef, cfg, dtype)[0])(rep_keys)
        unit_params.append(p)
        unit_specs.append(_prepend_none(layer_specs(ldef, cfg, dtype)))
    return unit_params, unit_specs


# ---------------------------------------------------------------------------
# per-layer apply
# ---------------------------------------------------------------------------


def init_layer_cache(ldef: LayerDef, cfg: ModelConfig, batch: int, length: int,
                     dtype):
    """Cache template (zeros) for one layer in decode mode."""
    if ldef.mixer == "attn":
        cache_len = min(length, ldef.window) if ldef.window else length
        c = {"kv": attn.init_kv_cache(cfg, batch, cache_len, dtype)}
        if ldef.cross:
            hd = cfg.resolved_head_dim
            c["cross_kv"] = {
                "k": jnp.zeros((batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, cfg.encoder_frames, cfg.num_kv_heads, hd), dtype),
            }
        return c
    if ldef.mixer == "mamba":
        return ssm_lib.init_ssm_state(cfg, batch, dtype)
    if ldef.mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(cfg, batch, dtype)
    if ldef.mixer == "slstm":
        return xlstm_lib.init_slstm_state(cfg, batch, dtype)
    raise ValueError(ldef.mixer)


def layer_cache_spec(ldef: LayerDef, batch_axis, seq_axis, head_axis=None):
    if ldef.mixer == "attn":
        c = {"kv": attn.kv_cache_spec(batch_axis, seq_axis, head_axis)}
        if ldef.cross:
            c["cross_kv"] = attn.kv_cache_spec(batch_axis, None)
        return c
    if ldef.mixer == "mamba":
        return ssm_lib.ssm_state_spec(batch_axis)
    if ldef.mixer == "mlstm":
        return {"C": P(batch_axis, None, None, None), "n": P(batch_axis, None, None),
                "conv": P(batch_axis, None, None)}
    if ldef.mixer == "slstm":
        return {k: P(batch_axis, None, None) for k in ("c", "n", "h", "m")}
    raise ValueError(ldef.mixer)


def to_ring(kv: dict, window: int) -> dict:
    """Arrange the last ``window`` positions of a full-seq KV into ring order."""
    S = kv["k"].shape[1]
    if S <= window:
        return kv
    tail = {k: v[:, -window:] for k, v in kv.items()}
    slots = (jnp.arange(S - window, S)) % window
    return {k: jnp.zeros_like(v).at[:, slots].set(v) for k, v in tail.items()}


def apply_layer(ldef: LayerDef, lparams, x, *, cfg: ModelConfig,
                pctx: ParallelContext, mode: str, cache, pos,
                enc_out=None, shared_params=None, use_kernel: bool = False,
                causal: bool = True, h_pre=None, pending=None,
                defer_psum: bool = False):
    """Returns (x, new_cache, aux_vec[2], pending_out).

    ``h_pre`` short-circuits the input RMSNorm: a caller that already holds
    ``rms_norm(x, norm1)`` (the fused dequant+restore+norm kernel at the
    butterfly boundary) passes it here so the norm never runs twice.

    ``pending``/``defer_psum`` implement psum overlap (opt-in): a dense
    attn+mlp layer returns its MLP output as an *unreduced* per-rank
    partial (``pending_out``) instead of psumming it in place; the next
    layer folds ``x + model_psum(pending)`` in at its top, before norm1 —
    the same value added one layer later, which frees the compiler to
    overlap the model-axis collective with the boundary's independent work
    (weight loads, cache indexing) instead of serializing on it.  Layers
    with in-place reductions (MoE) or no model-axis partials return a zero
    pending, so the carried structure is stable under scan."""
    aux = jnp.zeros((2,), jnp.float32)
    new_cache = None
    p = dict(lparams)
    if ldef.shared:
        p["mixer"] = shared_params["mixer"]
        p["ffn"] = shared_params["ffn"]

    if pending is not None:
        x = x + model_psum(pending, pctx)
    pending_out = jnp.zeros_like(x) if defer_psum else None

    h = h_pre if h_pre is not None else rms_norm(x, p["norm1"], cfg.rms_eps)
    rope = not cfg.is_encdec          # whisper uses sinusoid embeds, no RoPE
    if ldef.mixer == "attn":
        if mode == "decode":
            out, kv = attn.attention_decode(
                p["mixer"], h, cache["kv"], pos, cfg=cfg, window=ldef.window,
                rope=rope)
            new_cache = {"kv": kv}
        else:
            out, kv = attn.attention_fullseq(
                p["mixer"], h, cfg=cfg, window=ldef.window,
                use_kernel=use_kernel, causal=causal, rope=rope)
            if mode == "prefill":
                new_cache = {"kv": to_ring(kv, ldef.window) if ldef.window else kv}
        # tensor-parallel stages: wo is row-sharded, so `out` is this model
        # rank's partial sum (the kv cache stays a local whole-head slice)
        x = x + model_psum(out, pctx)
        if ldef.cross:
            hc = rms_norm(x, p["norm_cross"], cfg.rms_eps)
            if mode == "decode":
                ckv = cache["cross_kv"]
            else:
                ckv = attn.encoder_kv(p["cross"], enc_out, cfg=cfg)
            x = x + attn.cross_attention(p["cross"], hc, ckv, cfg=cfg)
            if mode == "prefill":
                new_cache["cross_kv"] = ckv
            elif mode == "decode":
                new_cache["cross_kv"] = ckv
        if ldef.ffn is not None:
            h2 = rms_norm(x, p["norm2"], cfg.rms_eps)
            if ldef.ffn == "mlp":
                # w_down row-sharded under tensor parallelism -> partial out
                part = apply_mlp(p["ffn"], h2, cfg.act)
                if defer_psum:
                    pending_out = part
                else:
                    x = x + model_psum(part, pctx)
            else:
                out, moe_aux = moe_lib.apply_moe(p["ffn"], h2, cfg=cfg,
                                                 pctx=pctx, act=cfg.act)
                x = x + out
                aux = aux + jnp.stack([moe_aux["load_balance"],
                                       moe_aux["router_z"]])
    elif ldef.mixer == "mamba":
        if mode == "decode":
            out, st = ssm_lib.mamba_decode(p["mixer"], h, cache, cfg=cfg)
            new_cache = st
        else:
            out, st = ssm_lib.mamba_fullseq(p["mixer"], h, cfg=cfg,
                                            return_state=(mode == "prefill"))
            new_cache = st
        x = x + out
    elif ldef.mixer == "mlstm":
        if mode == "decode":
            out, st = xlstm_lib.mlstm_decode(p["mixer"], h, cache, cfg=cfg)
        else:
            out, st = xlstm_lib.mlstm_fullseq(p["mixer"], h, cfg=cfg,
                                              return_state=(mode == "prefill"))
        new_cache = st
        x = x + out
    elif ldef.mixer == "slstm":
        if mode == "decode":
            out, st = xlstm_lib.slstm_decode(p["mixer"], h, cache, cfg=cfg)
        else:
            out, st = xlstm_lib.slstm_fullseq(p["mixer"], h, cfg=cfg,
                                              return_state=(mode == "prefill"))
        new_cache = st
        x = x + out
    else:
        raise ValueError(ldef.mixer)
    return x, new_cache, aux, pending_out


# ---------------------------------------------------------------------------
# segment / stage apply (scan over repeats)
# ---------------------------------------------------------------------------


def _apply_unit(seg: Segment, unit_params, unit_cache, x, aux_sum, pending, *,
                cfg, pctx, mode, pos, enc_out, shared_params, use_kernel,
                causal, first_h=None, defer_psum=False):
    """One pass over a segment's repeat unit; shared by the scan body and
    the peeled first repeat."""
    new_caches = []
    for i, ldef in enumerate(seg.unit):
        c = None if unit_cache is None else unit_cache[i]
        x, nc, aux, pending = apply_layer(
            ldef, unit_params[i], x, cfg=cfg, pctx=pctx, mode=mode,
            cache=c, pos=pos, enc_out=enc_out, shared_params=shared_params,
            use_kernel=use_kernel, causal=causal,
            h_pre=first_h if i == 0 else None, pending=pending,
            defer_psum=defer_psum)
        aux_sum = aux_sum + aux
        new_caches.append(nc)
    return x, aux_sum, pending, new_caches


def apply_segment(seg: Segment, seg_params, x, *, cfg, pctx, mode, seg_cache,
                  pos, enc_out=None, shared_params=None, use_kernel=False,
                  causal=True, first_h=None, overlap_psum=False,
                  pending=None):
    """seg_params: list per unit pos of stacked params; seg_cache likewise.

    ``first_h`` is the fused restore+norm kernel's pre-normed input for the
    segment's FIRST layer; when given, the first repeat is peeled out of the
    scan (a scan body takes one trace for all repeats, so the norm skip
    cannot live inside it) and the remaining repeats scan as usual.
    ``overlap_psum`` threads a deferred MLP partial (``pending``) through
    the repeats — see :func:`apply_layer`; the caller flushes the returned
    pending."""
    kw = dict(cfg=cfg, pctx=pctx, mode=mode, pos=pos, enc_out=enc_out,
              shared_params=shared_params, use_kernel=use_kernel,
              causal=causal, defer_psum=overlap_psum)
    if overlap_psum and pending is None:
        pending = jnp.zeros_like(x)
    aux0 = jnp.zeros((2,), jnp.float32)
    peel = first_h is not None
    if peel:
        p0 = jax.tree.map(lambda a: a[0], seg_params)
        c0 = None if seg_cache is None else \
            jax.tree.map(lambda a: a[0], seg_cache)
        x, aux0, pending, first_caches = _apply_unit(
            seg, p0, c0, x, aux0, pending, first_h=first_h, **kw)
        if seg.repeats == 1:
            new_cache = jax.tree.map(lambda a: a[None], first_caches)
            return x, new_cache, aux0, pending
        seg_params = jax.tree.map(lambda a: a[1:], seg_params)
        seg_cache = None if seg_cache is None else \
            jax.tree.map(lambda a: a[1:], seg_cache)

    def body(carry, xs):
        if overlap_psum:
            xc, pend, aux_sum = carry
        else:
            (xc, aux_sum), pend = carry, None
        unit_params, unit_cache = xs
        xc, aux_sum, pend, new_caches = _apply_unit(
            seg, unit_params, unit_cache, xc, aux_sum, pend, **kw)
        carry = (xc, pend, aux_sum) if overlap_psum else (xc, aux_sum)
        return carry, new_caches

    reps = seg.repeats - 1 if peel else seg.repeats
    init = (x, pending, aux0) if overlap_psum else (x, aux0)
    carry, new_cache = jax.lax.scan(body, init, (seg_params, seg_cache),
                                    length=reps, unroll=_scan_unroll(reps))
    if overlap_psum:
        x, pending, aux = carry
    else:
        (x, aux), pending = carry, None
    if peel:
        new_cache = jax.tree.map(
            lambda f, r: jnp.concatenate([f[None], r], axis=0),
            first_caches, new_cache)
    return x, new_cache, aux, pending


def apply_stage(segments: List[Segment], stage_params, x, *, cfg, pctx, mode,
                stage_cache, pos, enc_out=None, shared_params=None,
                use_kernel=False, causal=True, first_h=None,
                overlap_psum=False):
    aux_total = jnp.zeros((2,), jnp.float32)
    new_caches = []
    pending = None
    for si, seg in enumerate(segments):
        cache = None if stage_cache is None else stage_cache[si]
        x, nc, aux, pending = apply_segment(
            seg, stage_params[si], x, cfg=cfg, pctx=pctx, mode=mode,
            seg_cache=cache, pos=pos, enc_out=enc_out,
            shared_params=shared_params, use_kernel=use_kernel, causal=causal,
            first_h=first_h if si == 0 else None,
            overlap_psum=overlap_psum, pending=pending)
        new_caches.append(nc)
        aux_total = aux_total + aux
    if pending is not None:
        x = x + model_psum(pending, pctx)      # stage-end flush
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# stacked cache init/specs for a stage
# ---------------------------------------------------------------------------


def init_stage_cache(segments: List[Segment], cfg, batch, length, dtype):
    out = []
    for seg in segments:
        unit = []
        for ldef in seg.unit:
            c = init_layer_cache(ldef, cfg, batch, length, dtype)
            unit.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (seg.repeats,) + a.shape), c))
        out.append(unit)
    return out


def stage_cache_spec(segments: List[Segment], batch_axis, seq_axis,
                     head_axis=None):
    """``head_axis`` shards attention kv-head dims (tensor-parallel stages);
    recurrent mixer state has no head-sharded decomposition here and stays
    replicated."""
    out = []
    for seg in segments:
        unit = []
        for ldef in seg.unit:
            s = layer_cache_spec(ldef, batch_axis, seq_axis, head_axis)
            unit.append(_prepend_none(s))
        out.append(unit)
    return out
