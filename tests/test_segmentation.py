"""Property tests for the layer-stack segmentation (hypothesis): segments
must reconstruct the flat def list exactly for arbitrary patterns."""
import pytest

pytest.importorskip("hypothesis", reason="property suites need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.transformer import (LayerDef, Segment, build_layer_defs,
                                      segmentize, split_defs)

kinds = st.sampled_from([
    LayerDef(mixer="attn", ffn="mlp"),
    LayerDef(mixer="attn", ffn="moe"),
    LayerDef(mixer="attn", ffn="mlp", window=128),
    LayerDef(mixer="mamba", ffn=None),
    LayerDef(mixer="mlstm", ffn=None),
    LayerDef(mixer="slstm", ffn=None),
    LayerDef(mixer="attn", ffn="mlp", shared=True),
])


def _flatten(segments):
    out = []
    for s in segments:
        out.extend(list(s.unit) * s.repeats)
    return out


@settings(max_examples=100, deadline=None)
@given(st.lists(kinds, min_size=1, max_size=40))
def test_segmentize_reconstructs(defs):
    segs = segmentize(defs)
    assert _flatten(segs) == defs
    assert all(s.repeats >= 1 and len(s.unit) >= 1 for s in segs)


@settings(max_examples=50, deadline=None)
@given(st.lists(kinds, min_size=2, max_size=30), st.data())
def test_split_preserves_layers(defs, data):
    boundary = data.draw(st.integers(1, len(defs) - 1))
    stages = split_defs(defs, boundary)
    assert len(stages) == 2
    assert _flatten(stages[0]) == defs[:boundary]
    assert _flatten(stages[1]) == defs[boundary:]


def test_assigned_arch_patterns():
    """Spot-check the per-arch layer patterns against their cards."""
    g3 = build_layer_defs(get_config("gemma3-12b"))
    assert len(g3) == 48
    # 5 local : 1 global
    assert [d.window for d in g3[:6]] == [1024] * 5 + [None]
    zam = build_layer_defs(get_config("zamba2-7b"))
    assert len(zam) == 81
    assert sum(d.shared for d in zam) == 13           # shared attn blocks
    assert sum(d.mixer == "mamba" for d in zam) == 68
    xl = build_layer_defs(get_config("xlstm-125m"))
    assert [d.mixer for d in xl[:3]] == ["mlstm", "mlstm", "slstm"]
    l4 = build_layer_defs(get_config("llama4-maverick-400b-a17b"))
    assert sum(d.ffn == "moe" for d in l4) == 24      # MoE every other layer
    qm = build_layer_defs(get_config("qwen3-moe-235b-a22b"))
    assert all(d.ffn == "moe" for d in qm) and len(qm) == 94


def test_segment_counts_small():
    """Scan-friendliness: each arch compresses to few segments."""
    for arch in ("qwen3-14b", "gemma3-12b", "zamba2-7b", "xlstm-125m",
                 "qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b"):
        segs = segmentize(build_layer_defs(get_config(arch)))
        assert len(segs) <= 3, (arch, len(segs))
