"""Shared model primitives: norms, RoPE, glu mlps, initializers.

Params are plain nested dicts of jnp arrays; every init function returns
(params, specs) where specs is a parallel tree of
``jax.sharding.PartitionSpec`` used by the launcher for pjit in_shardings.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

# mesh axis sizes assumed by `maybe_axis`; the launcher guarantees the
# production mesh has model axis 16.  For smoke tests (1 device) everything
# is replicated anyway because the mesh has a single device.
MODEL_AXIS = "model"
DATA_AXIS = "data"
MODEL_AXIS_SIZE = 16


def maybe_axis(dim_size: int, axis: str = MODEL_AXIS, size: int = MODEL_AXIS_SIZE):
    """Shard a dim over `axis` only if divisible; else replicate."""
    return axis if dim_size % size == 0 else None


def dense_spec(shape: tuple, shard_dim: Optional[int], axis: str = MODEL_AXIS) -> P:
    parts = [None] * len(shape)
    if shard_dim is not None and shape[shard_dim] % MODEL_AXIS_SIZE == 0:
        parts[shard_dim] = axis
    return P(*parts)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, scale: float, dtype) -> jax.Array:
    std = math.sqrt(scale)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / d_in
    return trunc_normal(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype=jnp.float32):
    # zero-centered weight (gemma-style "1 + w") so init is identity
    return jnp.zeros((d,), dtype), P(None)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    angles = angles[..., :, None, :]                                # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d_model // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# gated mlp (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype, scale=1.0 / d_ff),
    }
    specs = {
        "w_gate": dense_spec((d_model, d_ff), 1),
        "w_up": dense_spec((d_model, d_ff), 1),
        "w_down": dense_spec((d_ff, d_model), 0),
    }
    return params, specs


def glu_act(gate: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(gate)
    if act == "gelu":
        return jax.nn.gelu(gate, approximate=True)
    raise ValueError(f"unknown act {act}")


def apply_mlp(params, x: jax.Array, act: str) -> jax.Array:
    gate = glu_act(x @ params["w_gate"], act)
    up = x @ params["w_up"]
    return (gate * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype):
    params = trunc_normal(key, (vocab, d_model), 1.0 / d_model, dtype)
    spec = dense_spec((vocab, d_model), 0)
    return params, spec


def embed(table: jax.Array, tokens: jax.Array, scale: bool = False) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    if scale:
        out = out * jnp.asarray(math.sqrt(table.shape[-1]), out.dtype)
    return out


def unembed(table: jax.Array, x: jax.Array, softcap: Optional[float] = None) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
