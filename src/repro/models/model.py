"""Top-level model build/init/apply for every assigned architecture.

Public API:
  build(cfg, long_mode)        -> BuiltModel (segmentation, metadata)
  init_model(key, built)       -> (params, param_specs)
  forward_train(params, built, batch, pctx)   -> (logits, aux)
  forward_prefill(params, built, batch, pctx) -> (logits, caches)
  forward_decode(params, built, tokens, caches, pos, pctx) -> (logits, caches)
  input_specs(built, shape, pctx) -> (batch tree of ShapeDtypeStruct, PartitionSpec tree)
  decode_state_specs(built, shape, pctx) -> (cache SDS tree, cache spec tree)

Modality frontends are stubs per the assignment carve-out: pixtral gets
precomputed patch embeddings, whisper gets precomputed frame embeddings —
both already at d_model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core import butterfly as bf_lib
from repro.models import attention as attn_lib
from repro.models import transformer as tfm
from repro.models.common import embed, init_embedding, init_rms_norm, rms_norm, \
    sinusoid_positions, trunc_normal, unembed
from repro.models.parallel import LOCAL, ParallelContext

# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BuiltModel:
    cfg: ModelConfig
    stages: tuple                     # tuple of tuple[Segment]
    enc_segments: tuple               # whisper encoder segments (or ())
    long_mode: bool = False

    @property
    def has_butterfly(self) -> bool:
        return self.cfg.butterfly is not None


def build(cfg: ModelConfig, long_mode: bool = False) -> BuiltModel:
    defs = tfm.build_layer_defs(cfg, long_mode=long_mode)
    boundary = cfg.butterfly.layer if cfg.butterfly is not None else None
    stages = tuple(tuple(s) for s in tfm.split_defs(defs, boundary))
    enc_segments = ()
    if cfg.is_encdec:
        enc_defs = [tfm.LayerDef(mixer="attn", ffn="mlp")] * cfg.encoder_layers
        enc_segments = tuple(tfm.segmentize(enc_defs))
    return BuiltModel(cfg=cfg, stages=stages, enc_segments=enc_segments,
                      long_mode=long_mode)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_model(key, built: BuiltModel):
    cfg = built.cfg
    dtype = _dtype(cfg)
    keys = iter(jax.random.split(key, 64))
    params: dict = {}
    specs: dict = {}

    params["embed"], specs["embed"] = init_embedding(next(keys), cfg.vocab_size,
                                                     cfg.d_model, dtype)
    params["final_norm"], specs["final_norm"] = init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"], specs["head"] = init_embedding(next(keys), cfg.vocab_size,
                                                       cfg.d_model, dtype)

    stage_params, stage_specs = [], []
    for segs in built.stages:
        seg_params, seg_specs = [], []
        for seg in segs:
            p, s = tfm.init_segment(next(keys), seg, cfg, dtype)
            seg_params.append(p)
            seg_specs.append(s)
        stage_params.append(seg_params)
        stage_specs.append(seg_specs)
    params["stages"], specs["stages"] = stage_params, stage_specs

    if cfg.butterfly is not None:
        params["butterfly"], specs["butterfly"] = bf_lib.init_butterfly(
            next(keys), cfg.d_model, cfg.butterfly, dtype)

    if cfg.hybrid_attn_every is not None:
        # zamba2: one shared attention + mlp param set
        from repro.models.common import init_mlp
        pa, sa = attn_lib.init_attention(next(keys), cfg, dtype)
        pm, sm = init_mlp(next(keys), cfg.d_model, cfg.d_ff, dtype)
        params["shared_attn"] = {"mixer": pa, "ffn": pm}
        specs["shared_attn"] = {"mixer": sa, "ffn": sm}

    if cfg.is_encdec:
        enc_p, enc_s = [], []
        for seg in built.enc_segments:
            p, s = tfm.init_segment(next(keys), seg, cfg, dtype)
            enc_p.append(p)
            enc_s.append(s)
        nw, ns = init_rms_norm(cfg.d_model, dtype)
        params["encoder"] = {"segments": enc_p, "final_norm": nw}
        specs["encoder"] = {"segments": enc_s, "final_norm": ns}

    return params, specs


def tp_param_specs(built: BuiltModel, *, with_butterfly: Optional[bool] = None):
    """PartitionSpec pytree matching :func:`init_model`'s params with every
    stage layer sharded tensor-parallel over the ``model`` axis (attention
    heads / d_ff columns / experts; see ``transformer.tp_layer_specs``) and
    everything else — embeddings, norms, LM head, butterfly — replicated.
    This is the in_specs tree manual shard_map stages feed params through
    (serving/pipeline.py, runtime/split_exec.py)."""
    from jax.sharding import PartitionSpec as P  # noqa: F811 (local alias)
    cfg = built.cfg
    assert not cfg.is_encdec, "enc-dec archs have no tensor-parallel stages"
    dt = _dtype(cfg)
    if with_butterfly is None:
        with_butterfly = built.has_butterfly
    specs: dict = {
        "embed": P(),
        "final_norm": P(),
        "stages": [tfm.tp_stage_specs(list(segs), cfg, dt)
                   for segs in built.stages],
    }
    if not cfg.tie_embeddings:
        specs["head"] = P()
    if with_butterfly:
        specs["butterfly"] = {"w_reduce": P(), "w_restore": P()}
    if cfg.hybrid_attn_every is not None:
        specs["shared_attn"] = {"mixer": attn_lib.tp_attention_specs(cfg),
                                "ffn": tfm.tp_mlp_specs()}
    return specs


# ---------------------------------------------------------------------------
# embedding frontends
# ---------------------------------------------------------------------------


def _embed_inputs(params, built: BuiltModel, batch: dict, pos0: int = 0):
    """Token (+stub modality) embeddings -> (B, S, d) residual stream input."""
    cfg = built.cfg
    scale = cfg.arch_type == "dense" and cfg.act == "gelu"   # gemma family
    x = embed(params["embed"], batch["tokens"], scale=scale)
    if cfg.is_encdec:
        S = x.shape[1]
        sin = sinusoid_positions(pos0 + S, cfg.d_model)[pos0:pos0 + S]
        x = x + sin[None].astype(x.dtype)
    if cfg.num_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def _encode(params, built: BuiltModel, frames, pctx, use_kernel=False):
    cfg = built.cfg
    sin = sinusoid_positions(frames.shape[1], cfg.d_model)
    x = frames.astype(_dtype(cfg)) + sin[None].astype(_dtype(cfg))
    for si, seg in enumerate(built.enc_segments):
        x, _, _, _ = tfm.apply_segment(
            seg, params["encoder"]["segments"][si], x, cfg=cfg, pctx=pctx,
            mode="train", seg_cache=None, pos=None, causal=False,
            use_kernel=use_kernel)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.rms_eps)


def _logits(params, built: BuiltModel, x):
    cfg = built.cfg
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(table, x, cfg.logit_softcap)


def _run_stages(params, built: BuiltModel, x, *, mode, pctx, caches, pos,
                enc_out, use_kernel, train: bool):
    cfg = built.cfg
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((2,), jnp.float32)
    rate = jnp.float32(0.0)
    new_caches = []
    for stage_idx, segs in enumerate(built.stages):
        if stage_idx == 1:
            if train and cfg.butterfly.rate_weight > 0:
                # entropy-rate of the wire codes under the codec prior;
                # recomputes the (cheap, d_r-wide) reduce matmul so the
                # serving-path apply_butterfly signature stays untouched
                from repro.core.wire_codec import rate_bits
                rate = rate_bits(x @ params["butterfly"]["w_reduce"],
                                 bits=cfg.butterfly.wire_bits)
            x = bf_lib.apply_butterfly(params["butterfly"], x,
                                       wire_bits=cfg.butterfly.wire_bits,
                                       train=train, use_kernel=use_kernel)
        stage_cache = None if caches is None else caches[stage_idx]
        x, nc, aux = tfm.apply_stage(
            list(segs), params["stages"][stage_idx], x, cfg=cfg, pctx=pctx,
            mode=mode, stage_cache=stage_cache, pos=pos, enc_out=enc_out,
            shared_params=shared, use_kernel=use_kernel)
        new_caches.append(nc)
        aux_total = aux_total + aux
    return x, new_caches, aux_total, rate


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def forward_train(params, built: BuiltModel, batch: dict,
                  pctx: ParallelContext = LOCAL, use_kernel: bool = False):
    cfg = built.cfg
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, built, batch["frames"], pctx, use_kernel)
    x = _embed_inputs(params, built, batch)
    x, _, aux, rate = _run_stages(params, built, x, mode="train", pctx=pctx,
                                  caches=None, pos=None, enc_out=enc_out,
                                  use_kernel=use_kernel, train=True)
    logits = _logits(params, built, x)
    return logits, {"load_balance": aux[0], "router_z": aux[1],
                    "wire_rate_bits": rate}


def forward_prefill(params, built: BuiltModel, batch: dict,
                    pctx: ParallelContext = LOCAL, use_kernel: bool = False):
    cfg = built.cfg
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, built, batch["frames"], pctx, use_kernel)
    x = _embed_inputs(params, built, batch)
    x, caches, _, _ = _run_stages(params, built, x, mode="prefill", pctx=pctx,
                                  caches=None, pos=None, enc_out=enc_out,
                                  use_kernel=use_kernel, train=False)
    logits = _logits(params, built, x[:, -1:])
    return logits, caches


def forward_decode(params, built: BuiltModel, tokens, caches, pos,
                   pctx: ParallelContext = LOCAL, use_kernel: bool = False):
    """tokens: (B, 1); pos: int32 scalar (absolute position of this token)."""
    cfg = built.cfg
    if cfg.is_encdec:
        # sinusoid position embedding at the (dynamic) absolute position
        import math as _math
        x = embed(params["embed"], tokens)
        dim = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)[None, :]
        inv = jnp.exp(-_math.log(10000.0) * dim / max(cfg.d_model // 2 - 1, 1))
        a = jnp.asarray(pos, jnp.float32) * inv
        sin = jnp.concatenate([jnp.sin(a), jnp.cos(a)], axis=-1)
        x = x + sin[None].astype(x.dtype)
    else:
        scale = cfg.arch_type == "dense" and cfg.act == "gelu"
        x = embed(params["embed"], tokens, scale=scale)
    x, new_caches, _, _ = _run_stages(params, built, x, mode="decode",
                                      pctx=pctx, caches=caches, pos=pos,
                                      enc_out=None, use_kernel=use_kernel,
                                      train=False)
    logits = _logits(params, built, x)
    return logits, new_caches


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(logits, targets, ignore: int = -1):
    """Cross entropy; targets == ignore are masked (vlm patch positions)."""
    mask = (targets != ignore)
    tgt = jnp.where(mask, targets, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for AOT lowering) + shardings
# ---------------------------------------------------------------------------


def input_specs(built: BuiltModel, shape: InputShape, pctx: ParallelContext):
    """Batch pytree of ShapeDtypeStruct + matching PartitionSpec tree."""
    cfg = built.cfg
    B, S = shape.global_batch, shape.seq_len
    dp = pctx.batch_spec_axes()
    bx = dp if (pctx.enabled and B % max(pctx.dp_size, 1) == 0 and B >= pctx.dp_size) else None
    sds, spec = {}, {}
    i32 = jnp.int32
    dt = _dtype(cfg)

    if shape.kind == "train":
        if cfg.num_patches:
            n_text = S - cfg.num_patches
            sds["tokens"] = jax.ShapeDtypeStruct((B, n_text), i32)
            sds["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), dt)
            spec["tokens"] = P(bx, None)
            spec["patches"] = P(bx, None, None)
        else:
            sds["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            spec["tokens"] = P(bx, None)
        if cfg.is_encdec:
            sds["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_frames, cfg.d_model), dt)
            spec["frames"] = P(bx, None, None)
        sds["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        spec["targets"] = P(bx, None)
    elif shape.kind == "prefill":
        if cfg.num_patches:
            sds["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.num_patches), i32)
            sds["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), dt)
            spec["tokens"] = P(bx, None)
            spec["patches"] = P(bx, None, None)
        else:
            sds["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            spec["tokens"] = P(bx, None)
        if cfg.is_encdec:
            sds["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_frames, cfg.d_model), dt)
            spec["frames"] = P(bx, None, None)
    else:  # decode
        sds["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        spec["tokens"] = P(bx, None)
    return sds, spec


def decode_state_specs(built: BuiltModel, shape: InputShape,
                       pctx: ParallelContext, seq_axis=None):
    """Cache ShapeDtypeStructs + PartitionSpecs for a decode serve_step."""
    cfg = built.cfg
    B, S = shape.global_batch, shape.seq_len
    dp = pctx.batch_spec_axes()
    bx = dp if (pctx.enabled and B % max(pctx.dp_size, 1) == 0 and B >= pctx.dp_size) else None
    dt = _dtype(cfg)

    def mk():
        return [tfm.init_stage_cache(list(segs), cfg, B, S, dt)
                for segs in built.stages]

    sds = jax.eval_shape(mk)
    specs = [tfm.stage_cache_spec(list(segs), bx, seq_axis)
             for segs in built.stages]
    return sds, specs
