from repro.models.model import (
    BuiltModel,
    build,
    decode_state_specs,
    forward_decode,
    forward_prefill,
    forward_train,
    init_model,
    input_specs,
    lm_loss,
)
from repro.models.parallel import LOCAL, ParallelContext, make_context

__all__ = [
    "BuiltModel", "build", "decode_state_specs", "forward_decode",
    "forward_prefill", "forward_train", "init_model", "input_specs",
    "lm_loss", "LOCAL", "ParallelContext", "make_context",
]
