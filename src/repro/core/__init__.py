# The paper's primary contribution: the butterfly unit (reduction/restoration
# bottleneck + int8 wire), Algorithm 1 (train/profile/select partitioning),
# and the wireless/roofline profiling substrate.
from repro.core.butterfly import (
    apply_butterfly,
    butterfly_wire_bytes,
    compression_ratio,
    init_butterfly,
    reduce_unit,
    restore_unit,
)
from repro.core.quantization import dequantize, fake_quant, quantize, wire_bytes

__all__ = [
    "apply_butterfly", "butterfly_wire_bytes", "compression_ratio",
    "init_butterfly", "reduce_unit", "restore_unit",
    "dequantize", "fake_quant", "quantize", "wire_bytes",
]
