"""Deterministic discrete-event simulation core.

A single virtual clock advances only when events fire; equal-time events run
in submission order (FIFO tie-break), so a simulation with a fixed seed
produces bit-identical traces on every host — the property the runtime tests
and the benchmark's cloud-only/split comparisons rely on.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class EventLoop:
    """Min-heap of ``(time, seq, fn)``; ``seq`` makes ordering total."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._processed = 0

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now:
            raise ValueError(f"cannot schedule at {t} < now {self.now}")
        heapq.heappush(self._heap, (float(t), next(self._seq), fn))

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self.schedule_at(self.now + delay, fn)

    def schedule_every(self, interval: float, fn: Callable[[], None],
                       first_delay: Optional[float] = None) -> Callable[[], None]:
        """Fire ``fn`` every ``interval`` of virtual time until the returned
        cancel callable is invoked.  The periodic event re-arms itself, so a
        caller (e.g. the metrics sampler) MUST cancel it when the workload
        drains — otherwise :meth:`run` never sees an empty queue."""
        if interval <= 0:
            raise ValueError(f"non-positive interval {interval}")
        live = [True]

        def tick() -> None:
            if not live[0]:
                return
            fn()
            self.schedule(interval, tick)

        self.schedule(interval if first_delay is None else first_delay, tick)
        return lambda: live.__setitem__(0, False)

    def empty(self) -> bool:
        return not self._heap

    @property
    def events_processed(self) -> int:
        return self._processed

    def step(self) -> bool:
        """Fire the next event; returns False when the queue is empty."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.now = t
        self._processed += 1
        fn()
        return True

    def run(self, until: Optional[float] = None,
            max_events: int = 10_000_000) -> float:
        """Drain the queue (or stop at virtual time ``until``); returns the
        final clock value."""
        while self._heap and self._processed < max_events:
            if until is not None and self._heap[0][0] > until:
                self.now = until
                return self.now
            self.step()
        if self._heap:
            raise RuntimeError(f"event budget exhausted ({max_events})")
        return self.now
