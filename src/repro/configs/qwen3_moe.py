"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, fine-grained experts
(d_ff_expert=1536). [hf:Qwen/Qwen3-30B-A3B family card, 235B row]"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("qwen3-moe-235b-a22b")
def qwen3_moe() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        arch_type="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,                    # per-expert ffn width (no dense ffn)
        vocab_size=151936,
        qk_norm=True,
        act="silu",
        rope_theta=1e6,
        tie_embeddings=False,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            d_ff_expert=1536,
            shared_expert_ff=0,
            every=1,
        ),
        source="hf:Qwen/Qwen3-30B-A3B (family card, 235B-A22B row)",
    )
