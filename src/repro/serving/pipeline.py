"""The paper's deployment, TPU-native: a 2-stage microbatched pipeline over
the ``pod`` mesh axis with the butterfly unit at the stage boundary.

Pod 0 ("edge") computes layers [0, j) + the reduction unit + int8 wire
quantization; a single ``lax.ppermute`` per tick carries ONLY the quantized
codes + f32 scales across the pod boundary (this is the paper's compressed
uplink, visible in the HLO as a collective-permute of an int8 tensor);
pod 1 ("cloud") dequantizes, restores, runs layers [j, N) and the LM head,
and the last-token logits ride the same ppermute back ("the inference
outcome is sent back to the mobile device").

Within a pod, stages are model-parallel (DESIGN.md section 11): when the
mesh carries a ``model`` axis, attention heads / d_ff columns / MoE experts
shard over it Megatron-style and each layer's partial outputs psum over
``model`` — so the "significant computational load on the cloud server"
spreads across the pod's devices while the *only* tensor crossing the pod
axis is still the compressed ``(mb, S, d_r)`` wire.  MoE configs run
expert-parallel inside the 2-pod split (each model rank owns E/mp experts,
``models/moe.py`` manual path).  With no ``model`` axis (or size 1) the
stage params replicate exactly as before.

Decode pipelining (:func:`make_decode_pipeline`): with >= 2 in-flight
microbatches rotating through the 2-pod mesh, pod 0 runs the edge decode
step for microbatch k+1 while pod 1 runs the cloud step for microbatch k —
one ppermute of int8 (or nibble-packed int4) codes per tick instead of the
serial ping-pong that idles one pod every token.  ``pipelined=False`` runs
the same per-step math one microbatch at a time (the serial reference), so
the two schedules are greedy-bitwise comparable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.quantization import (dequantize, pack_int4, quantize,
                                     unpack_int4, wire_bytes)
from repro.kernels import ops
from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.common import embed, rms_norm, unembed
from repro.models.parallel import LOCAL, manual_context


def wire_stats(cfg, microbatch: int, seq: int,
               wire_bits: Optional[int] = None) -> dict:
    """Bytes crossing the pod boundary per microbatch tick: ceil-packed
    codes (two int4 codes per byte — sub-byte wires no longer floor to 0)
    plus per-row scales at their real dtype width (f32)."""
    d_r = cfg.butterfly.d_r
    bits = cfg.butterfly.wire_bits if wire_bits is None else wire_bits
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    wire = wire_bytes((microbatch, seq, d_r), bits)
    raw = microbatch * seq * cfg.d_model * act_bytes
    return {"wire_bytes": wire, "raw_boundary_bytes": raw,
            "compression": raw / wire}


def pipeline_param_specs(built: M.BuiltModel, mp: int):
    """PartitionSpec pytree (a prefix of the params tree) for the pipeline's
    shard_map: stage layers shard over the ``model`` axis per the tensor-
    parallel rules, everything else (embeddings, norms, butterfly, LM head)
    replicates.  ``mp == 1`` returns a bare ``P()`` — the fully replicated
    prefix, bit-identical to the pre-model-parallel pipeline."""
    if mp <= 1:
        return P()
    return M.tp_param_specs(built)


def make_split_pipeline(built: M.BuiltModel, mesh, num_microbatches: int,
                        seq_len: int, microbatch: int,
                        wire_mode: str = "int8"):
    """Returns jit-able ``pipeline_fn(params, tokens) -> last-token logits``.

    tokens: (num_microbatches * microbatch, seq_len) int32, sharded over the
    'data' axis on the batch dim; requires a 'pod' axis of size 2.  An
    optional 'model' axis makes each stage tensor-parallel within its pod
    (heads/d_ff/experts must divide the axis — see
    ``transformer.check_tp_divisibility``).

    wire_mode — what crosses the pod boundary (the perf-iteration knob):
      "raw"     vanilla collaborative intelligence: the full (mb, S, d_model)
                activation in model dtype (prior work [6]-[12])
      "reduced" butterfly reduction only, no quantization: (mb, S, d_r) dtype
      "int8"    the paper: reduction + int8 wire (codes + f32 scales)
      "int4"    reduction + 4-bit wire: codes quantize to [-8, 7] and pack
                two per byte, halving per-token uplink bytes vs int8
    """
    cfg = built.cfg
    assert built.has_butterfly and len(built.stages) == 2, \
        "pipeline needs a butterfly split (cfg.with_butterfly(...))"
    assert not cfg.is_encdec, "enc-dec archs are out of pipeline scope"
    n_pods = mesh.shape["pod"]
    assert n_pods == 2, "2-stage pipeline: edge pod + cloud pod"
    axes = mesh.axis_names
    mp = int(mesh.shape["model"]) if "model" in axes else 1
    tfm.check_tp_divisibility(tfm.build_layer_defs(cfg, built.long_mode),
                              cfg, mp)
    pctx = manual_context(mesh) if mp > 1 else LOCAL
    d_r = cfg.butterfly.d_r
    V = cfg.vocab_size
    d = cfg.d_model
    Mmb = num_microbatches
    dt = jnp.dtype(cfg.dtype)

    # "entropy" shares the int8 numerics end to end (rANS is lossless over
    # the codes); it only changes byte accounting outside the graph
    assert wire_mode in ("raw", "reduced", "int8", "int4", "entropy"), \
        wire_mode
    if wire_mode == "int4":
        assert d_r % 2 == 0, "int4 wire packs two codes per byte"
    bits = 4 if wire_mode == "int4" else cfg.butterfly.wire_bits

    def stage_edge(params, toks):
        scale = cfg.arch_type == "dense" and cfg.act == "gelu"
        x = embed(params["embed"], toks, scale=scale)
        x, _, _ = tfm.apply_stage(
            list(built.stages[0]), params["stages"][0], x, cfg=cfg,
            pctx=pctx, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        if wire_mode == "raw":
            return x, jnp.zeros((x.shape[0], seq_len, 1), jnp.float32)
        r = x @ params["butterfly"]["w_reduce"]
        if wire_mode == "reduced":
            return r, jnp.zeros((r.shape[0], seq_len, 1), jnp.float32)
        codes, scales = quantize(r, bits)
        if wire_mode == "int4":
            codes = pack_int4(codes)
        return codes, scales

    def stage_cloud(params, codes, scales):
        if wire_mode == "raw":
            x = codes
            x, _, _ = tfm.apply_stage(
                list(built.stages[1]), params["stages"][1], x, cfg=cfg,
                pctx=pctx, mode="train", stage_cache=None, pos=None,
                shared_params=params.get("shared_attn"))
            x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
            table = params["embed"] if cfg.tie_embeddings else params["head"]
            return unembed(table, x)[:, 0]
        if wire_mode == "int4":
            codes = unpack_int4(codes)
        r = codes if wire_mode == "reduced" else dequantize(codes, scales, dt)
        x = r @ params["butterfly"]["w_restore"]
        x, _, _ = tfm.apply_stage(
            list(built.stages[1]), params["stages"][1], x, cfg=cfg,
            pctx=pctx, mode="train", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(table, x)[:, 0]                      # (mb, V)

    def shard_body(params, tokens):
        pod = jax.lax.axis_index("pod")
        mb_toks = tokens.reshape(Mmb, -1, seq_len)
        mb = mb_toks.shape[1]

        if wire_mode == "raw":
            wire_shape, wire_dtype = (mb, seq_len, d), dt
        elif wire_mode == "reduced":
            wire_shape, wire_dtype = (mb, seq_len, d_r), dt
        elif wire_mode == "int4":
            wire_shape, wire_dtype = (mb, seq_len, d_r // 2), jnp.int8
        else:
            wire_shape, wire_dtype = (mb, seq_len, d_r), jnp.int8
        zero_wire = (jnp.zeros(wire_shape, wire_dtype),
                     jnp.zeros((mb, seq_len, 1), jnp.float32))
        zero_logits = jnp.zeros((mb, V), jnp.float32)

        def tick(t, carry):
            recv_codes, recv_scales, out, back = carry

            # each branch runs only on its pod's ranks; the model-axis psums
            # inside the stages reduce within the pod (disjoint replica
            # groups per pod), so neither branch communicates across pods
            def edge(_):
                i = jnp.clip(t, 0, Mmb - 1)
                toks = jax.lax.dynamic_index_in_dim(mb_toks, i, 0, False)
                codes, scales = stage_edge(params, toks)
                return codes, scales, zero_logits

            def cloud(_):
                logits = stage_cloud(params, recv_codes, recv_scales)
                return zero_wire[0], zero_wire[1], logits

            codes, scales, logits = jax.lax.cond(pod == 0, edge, cloud, None)
            # the wire: int8 codes + scales cross 0 -> 1; logits cross 1 -> 0
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])
            logits_back = jax.lax.ppermute(logits, "pod", [(0, 1), (1, 0)])
            out = jnp.where(t >= 1, out.at[jnp.maximum(t - 1, 0)].set(logits),
                            out)
            back = jnp.where(t >= 1, back.at[jnp.maximum(t - 1, 0)].set(logits_back),
                             back)
            return codes, scales, out, back

        out0 = jnp.zeros((Mmb, mb, V), jnp.float32)
        carry = (*zero_wire, out0, out0)
        *_, out, back = jax.lax.fori_loop(0, Mmb + 1, tick, carry)
        # pod 1 filled `out` locally; pod 0 received `back`. Select the live
        # copy so the caller-visible result is pod-invariant.
        result = jnp.where(pod == 0, back, out)
        return result[None]                                  # add pod dim

    data_ax = "data" if "data" in axes else None
    fn = compat.shard_map(
        shard_body, mesh=mesh,
        in_specs=(pipeline_param_specs(built, mp), P(data_ax, None)),
        out_specs=P("pod", None, data_ax, None),
        check_vma=False,
    )

    def pipeline_fn(params, tokens):
        res = fn(params, tokens)
        return res[0].reshape(-1, V)                         # pod 0's copy

    return pipeline_fn


def _grow_cache(small, template):
    """Zero-pad a prefill-time stage cache into a decode-capacity template
    (seq axis grows from prompt_len to prompt_len + new_tokens; ring-window
    and state caches already match).  Padding is safe because decode masks
    cache slots beyond the current position."""
    def copy(big, sm):
        pads = [(0, b - s) for b, s in zip(big.shape, sm.shape)]
        if any(p for _, p in pads):
            sm = jnp.pad(sm, pads)
        return sm.astype(big.dtype)
    return jax.tree.map(copy, template, small)


def make_decode_pipeline(built: M.BuiltModel, mesh, num_microbatches: int,
                         prompt_len: int, microbatch: int, new_tokens: int,
                         wire_mode: str = "int8", pipelined: bool = True,
                         use_kernel: bool = False,
                         overlap_psum: bool = False):
    """Returns ``decode_fn(params, tokens) -> greedy token ids``.

    tokens: (num_microbatches * microbatch, prompt_len) int32 prompts; the
    result is (num_microbatches * microbatch, new_tokens) int32 — column 0
    is the token greedily decoded from the prefill logits, the rest come
    from per-token decode steps through the split.

    Schedule (``pipelined=True``, needs >= 2 microbatches): decode runs one
    fori_loop over ticks t.  At tick t pod 0 (edge) runs the embed+stage-0
    decode step for microbatch ``t % M`` round ``t // M`` and emits its
    quantized boundary row; pod 1 (cloud) *concurrently* runs stage-1 +
    LM head on the row it received at the end of tick t-1 (microbatch
    ``(t-1) % M``).  One ppermute carries the fresh codes 0 -> 1 and the
    decoded token 1 -> 0 per tick, so both pods stay busy every tick.  The
    M-1 tick gap between a token's decode and its reuse by the edge is what
    makes >= 2 in-flight microbatches mandatory.

    ``pipelined=False`` is the serial reference: each tick runs edge ->
    ppermute -> cloud -> ppermute-back for a single microbatch, so one pod
    always idles.  Both modes share the same per-step closures and visit
    the same (microbatch, position) pairs in the same order, so greedy
    outputs are bitwise identical.

    ``wire_mode``: "int8" or nibble-packed "int4" (halves uplink bytes).
    ``use_kernel``: fused reduce+quant on the edge and fused
    dequant+restore+norm1 (``ops.butterfly_restore_norm``) on the cloud.
    ``overlap_psum``: defer each dense layer's MLP psum into the next layer
    (see ``transformer.apply_layer``).
    """
    cfg = built.cfg
    assert built.has_butterfly and len(built.stages) == 2, \
        "decode pipeline needs a butterfly split (cfg.with_butterfly(...))"
    assert not cfg.is_encdec, "enc-dec archs are out of pipeline scope"
    assert mesh.shape["pod"] == 2, "2-stage pipeline: edge pod + cloud pod"
    axes = mesh.axis_names
    mp = int(mesh.shape["model"]) if "model" in axes else 1
    tfm.check_tp_divisibility(tfm.build_layer_defs(cfg, built.long_mode),
                              cfg, mp)
    pctx = manual_context(mesh) if mp > 1 else LOCAL
    d_r = cfg.butterfly.d_r
    S = int(prompt_len)
    T = int(new_tokens)
    Mmb = int(num_microbatches)
    dt = jnp.dtype(cfg.dtype)
    assert wire_mode in ("int8", "int4", "entropy"), wire_mode
    if wire_mode == "int4":
        assert d_r % 2 == 0, "int4 wire packs two codes per byte"
    bits = 4 if wire_mode == "int4" else 8
    wire_cols = d_r // 2 if wire_mode == "int4" else d_r
    assert T >= 2, "need at least one decode tick"
    if pipelined:
        assert Mmb >= 2, "pipelined decode needs >= 2 in-flight microbatches"
    stages0 = list(built.stages[0])
    stages1 = list(built.stages[1])
    embed_scale = cfg.arch_type == "dense" and cfg.act == "gelu"

    def edge_wire(params, x):
        if use_kernel:
            codes, scales = ops.butterfly_reduce_quant(
                x, params["butterfly"]["w_reduce"], bits=bits)
        else:
            r = x @ params["butterfly"]["w_reduce"]
            codes, scales = quantize(r, bits)
        if wire_mode == "int4":
            codes = pack_int4(codes)
        return codes, scales

    def cloud_restore(params, codes, scales):
        if wire_mode == "int4":
            codes = unpack_int4(codes)
        if use_kernel:
            nw = tfm.first_layer_norm1(stages1, params["stages"][1])
            x, h = ops.butterfly_restore_norm(
                codes, scales, params["butterfly"]["w_restore"], nw,
                eps=cfg.rms_eps, out_dtype=dt)
        else:
            r = dequantize(codes, scales, dt)
            x = r @ params["butterfly"]["w_restore"]
            h = None
        return x, h

    def greedy(params, x):
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(table, x, cfg.logit_softcap)[:, 0]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def edge_prefill(params, toks):
        x = embed(params["embed"], toks, scale=embed_scale)
        x, caches, _ = tfm.apply_stage(
            stages0, params["stages"][0], x, cfg=cfg, pctx=pctx,
            mode="prefill", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"))
        codes, scales = edge_wire(params, x)
        return codes, scales, caches

    def cloud_prefill(params, codes, scales):
        x, h = cloud_restore(params, codes, scales)
        x, caches, _ = tfm.apply_stage(
            stages1, params["stages"][1], x, cfg=cfg, pctx=pctx,
            mode="prefill", stage_cache=None, pos=None,
            shared_params=params.get("shared_attn"), first_h=h,
            overlap_psum=overlap_psum)
        return greedy(params, x), caches

    def edge_step(params, tok, cache, pos):
        x = embed(params["embed"], tok[:, None], scale=embed_scale)
        x, cache, _ = tfm.apply_stage(
            stages0, params["stages"][0], x, cfg=cfg, pctx=pctx,
            mode="decode", stage_cache=cache, pos=pos,
            shared_params=params.get("shared_attn"))
        codes, scales = edge_wire(params, x)
        return codes, scales, cache

    def cloud_step(params, codes, scales, cache, pos):
        x, h = cloud_restore(params, codes, scales)
        x, cache, _ = tfm.apply_stage(
            stages1, params["stages"][1], x, cfg=cfg, pctx=pctx,
            mode="decode", stage_cache=cache, pos=pos,
            shared_params=params.get("shared_attn"), first_h=h,
            overlap_psum=overlap_psum)
        return greedy(params, x), cache

    def _at(tree, i):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False), tree)

    def _put(tree, upd, i, keep):
        def one(a, u):
            new = jax.lax.dynamic_update_index_in_dim(a, u, i, 0)
            return jnp.where(keep, new, a)
        return jax.tree.map(one, tree, upd)

    n_ticks = Mmb * (T - 1)

    def shard_body(params, tokens):
        pod = jax.lax.axis_index("pod")
        mb_toks = tokens.reshape(Mmb, -1, S)
        mb = mb_toks.shape[1]
        zero_prefill_wire = (jnp.zeros((mb, S, wire_cols), jnp.int8),
                             jnp.zeros((mb, S, 1), jnp.float32))
        zero_row_wire = (jnp.zeros((mb, 1, wire_cols), jnp.int8),
                        jnp.zeros((mb, 1, 1), jnp.float32))
        zero_tok = jnp.zeros((mb,), jnp.int32)
        # Each model rank caches only its own KV-head slice, so size the
        # decode templates with per-rank head counts (recurrent-mixer states
        # replicate per rank and keep their global shapes).
        cfg_rank = (dataclasses.replace(cfg, num_kv_heads=cfg.num_kv_heads // mp)
                    if mp > 1 else cfg)
        tmpl0 = tfm.init_stage_cache(stages0, cfg_rank, mb, S + T, dt)
        tmpl1 = tfm.init_stage_cache(stages1, cfg_rank, mb, S + T, dt)

        # ---- prefill: build both pods' decode caches + token_0 per mb ----
        toks0, c0_list, c1_list = [], [], []
        for k in range(Mmb):
            toks = mb_toks[k]

            def p_edge(_):
                codes, scales, caches = edge_prefill(params, toks)
                return codes, scales, _grow_cache(caches, tmpl0)

            def p_skip_e(_):
                return (*zero_prefill_wire, tmpl0)

            codes, scales, c0k = jax.lax.cond(pod == 0, p_edge, p_skip_e, None)
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])

            def p_cloud(_):
                tok0, caches = cloud_prefill(params, codes, scales)
                return tok0, _grow_cache(caches, tmpl1)

            def p_skip_c(_):
                return zero_tok, tmpl1

            tok0, c1k = jax.lax.cond(pod == 1, p_cloud, p_skip_c, None)
            tok_back = jax.lax.ppermute(tok0, "pod", [(0, 1), (1, 0)])
            toks0.append(jnp.where(pod == 0, tok_back, tok0))
            c0_list.append(c0k)
            c1_list.append(c1k)

        c0 = jax.tree.map(lambda *xs: jnp.stack(xs), *c0_list)
        c1 = jax.tree.map(lambda *xs: jnp.stack(xs), *c1_list)
        tok = jnp.stack(toks0)                               # (Mmb, mb)
        out = jnp.zeros((Mmb, T, mb), jnp.int32).at[:, 0].set(tok)

        # ---- decode ticks ----
        def run_edge(t, tok, c0):
            k = jnp.mod(t, Mmb)
            pos = S + jnp.clip(t // Mmb, 0, T - 2)           # scalar, aligned
            codes, scales, cache = edge_step(params, _at(tok, k), _at(c0, k),
                                             pos)
            return codes, scales, _put(c0, cache, k, t < n_ticks)

        def run_cloud(t, codes, scales, c1, active):
            # `active` gates the cache write: a warm-up tick fed zero codes
            # must not advance recurrent (ssm/xlstm) states
            k = jnp.mod(t, Mmb)
            pos = S + jnp.clip(t // Mmb, 0, T - 2)
            tok_next, cache = cloud_step(params, codes, scales, _at(c1, k),
                                         pos)
            return tok_next, _put(c1, cache, k, active)

        def commit(t, tok_next, tok, out, active):
            # both pods fold the decoded token into their (identical) copy
            k, j = jnp.mod(t, Mmb), t // Mmb
            tok = jnp.where(active, tok.at[k].set(tok_next), tok)
            out = jnp.where(active, out.at[k, j + 1].set(tok_next), out)
            return tok, out

        def tick_pipelined(t, carry):
            codes_in, scales_in, tok, out, c0, c1 = carry
            tc = jnp.maximum(t - 1, 0)                       # cloud serves t-1

            def edge(_):
                codes, scales, new_c0 = run_edge(t, tok, c0)
                return codes, scales, zero_tok, new_c0, c1

            def cloud(_):
                tok_next, new_c1 = run_cloud(tc, codes_in, scales_in, c1,
                                             t >= 1)
                return (*zero_row_wire, tok_next, c0, new_c1)

            codes, scales, tok_next, c0n, c1n = jax.lax.cond(
                pod == 0, edge, cloud, None)
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])
            tok_back = jax.lax.ppermute(tok_next, "pod", [(0, 1), (1, 0)])
            tok_val = jnp.where(pod == 0, tok_back, tok_next)
            tok, out = commit(tc, tok_val, tok, out, t >= 1)
            return codes, scales, tok, out, c0n, c1n

        def tick_serial(t, carry):
            _, _, tok, out, c0, c1 = carry

            def edge(_):
                codes, scales, new_c0 = run_edge(t, tok, c0)
                return codes, scales, new_c0

            def skip_e(_):
                return (*zero_row_wire, c0)

            codes, scales, c0 = jax.lax.cond(pod == 0, edge, skip_e, None)
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scales = jax.lax.ppermute(scales, "pod", [(0, 1), (1, 0)])

            def cloud(_):
                return run_cloud(t, codes, scales, c1, True)

            def skip_c(_):
                return zero_tok, c1

            tok_next, c1 = jax.lax.cond(pod == 1, cloud, skip_c, None)
            tok_back = jax.lax.ppermute(tok_next, "pod", [(0, 1), (1, 0)])
            tok_val = jnp.where(pod == 0, tok_back, tok_next)
            tok, out = commit(t, tok_val, tok, out, True)
            return codes, scales, tok, out, c0, c1

        carry = (*zero_row_wire, tok, out, c0, c1)
        tick = tick_pipelined if pipelined else tick_serial
        # pipelined: one extra drain tick so the cloud finishes the last row
        carry = jax.lax.fori_loop(0, n_ticks + (1 if pipelined else 0),
                                  tick, carry)
        out = carry[3]
        return jnp.transpose(out, (0, 2, 1))[None]           # (1, Mmb, mb, T)

    data_ax = "data" if "data" in axes else None
    fn = compat.shard_map(
        shard_body, mesh=mesh,
        in_specs=(pipeline_param_specs(built, mp), P(data_ax, None)),
        out_specs=P("pod", None, data_ax, None),
        check_vma=False,
    )

    def decode_fn(params, tokens):
        res = fn(params, tokens)
        return res[0].reshape(-1, T)                         # pod 0's copy

    return decode_fn
