"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (16 kv heads = 16 q heads).
[arXiv:2403.08295]"""
from repro.configs.base import ModelConfig, register


@register("gemma-7b")
def gemma_7b() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        arch_type="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        act="gelu",                   # GeGLU
        rope_theta=1e4,
        tie_embeddings=True,
        source="arXiv:2403.08295 (Gemma 7B: 28L d=3072 16H hd=256 ff=24576)",
    )
